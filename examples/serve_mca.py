"""Serving example: batched generation with and without MCA, reporting the
encoding-FLOPs reduction of the prefill (the paper's deployment story:
MCA is a drop-in inference-time switch — no retraining).

Run:  PYTHONPATH=src python examples/serve_mca.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.policy import MCAConfig
from repro.models import build_model, reduced
from repro.serve import Engine

ARCH = "chatglm3-6b"

cfg_off = reduced(get_config(ARCH))
model = build_model(cfg_off)
params = model.init(jax.random.PRNGKey(0))

# brief training so logits have real margins (a random net's argmax flips
# under any perturbation, which would make the comparison meaningless)
from repro.data import SyntheticLM
from repro.optim import adamw
from repro.train.step import make_train_step

data = SyntheticLM(cfg_off.vocab_size, 48, 8, seed=0)
step = jax.jit(make_train_step(model, adamw.AdamWConfig(lr=3e-3)),
               donate_argnums=(0, 1))
opt = adamw.init_state(params)
for i in range(40):
    params, opt, m = step(params, opt,
                          jax.tree.map(jax.numpy.asarray, data.batch(i)))
print(f"warmup train loss {float(m['total_loss']):.3f}")

rng = np.random.default_rng(0)
prompts = np.asarray(data.batch(99)["tokens"][:2, :48])

# exact serving
eng = Engine(model, params, batch_size=2, max_len=96)
t0 = time.time()
out_exact = eng.generate(prompts, max_new=12)
t_exact = time.time() - t0

# MCA serving: same params, approximation switched on
cfg_on = cfg_off.replace(mca=MCAConfig(enabled=True, alpha=0.3, block=16,
                                       sites=("v_proj",)))
model_on = build_model(cfg_on)
eng_on = Engine(model_on, params, batch_size=2, max_len=96,
                mca_enabled=True)
from repro import obs
t0 = time.time()
with obs.scoped() as reg:
    out_mca = eng_on.generate(prompts, max_new=12)
    snap = reg.snapshot()
t_mca = time.time() - t0
print(f"serve.flops_reduction (prefill): "
      f"{snap['gauges']['serve.flops_reduction']:.2f}x")
print("serve.tier_occupancy:",
      {k.rsplit('.', 1)[-1]: int(v) for k, v in snap["counters"].items()
       if k.startswith("serve.tier_occupancy.")})
print(f"decode p50 "
      f"{snap['histograms']['serve.decode_step_seconds']['p50'] * 1e3:.1f}ms"
      f"/step")

print(f"exact  : {out_exact[0].tolist()}")
print(f"mca    : {out_mca[0].tolist()}")
print(f"wall (CPU, structural only): exact {t_exact:.2f}s vs "
      f"mca {t_mca:.2f}s")

# teacher-forced fidelity: same context, exact vs MCA next-token argmax.
# (free-running generations diverge after any flipped token by
# construction, so per-position agreement there is not meaningful.)
ctx = {"tokens": jax.numpy.asarray(data.batch(123)["tokens"][:2])}
hid_e, _, _ = model.forward_hidden(params, ctx)
hid_m, _, _ = build_model(cfg_on).forward_hidden(params, ctx,
                                                 jax.random.PRNGKey(3))
from repro.models.api import _logits
pred_e = np.asarray(jax.numpy.argmax(
    _logits(params, cfg_off, hid_e)[..., :cfg_off.vocab_size], -1))
pred_m = np.asarray(jax.numpy.argmax(
    _logits(params, cfg_on, hid_m)[..., :cfg_on.vocab_size], -1))
agree = float((pred_e == pred_m).mean())
print(f"teacher-forced next-token agreement at alpha=0.3: {agree:.2f} "
      f"(rises toward 1.0 as alpha -> 0)")

# measure the prefill FLOPs reduction (the paper's metric) directly
loss_batch = {"tokens": jax.numpy.asarray(prompts),
              "labels": jax.numpy.asarray(prompts)}
_, metrics = jax.jit(lambda p, b, k: model_on.loss(p, b, k))(
    params, loss_batch, jax.random.PRNGKey(1))
red = float(metrics["mca_exact_flops"] / metrics["mca_flops"])
print(f"attention-encoding FLOPs reduction at alpha=0.3: {red:.2f}x")
