"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the full production stack — data pipeline, AdamW + cosine schedule,
microbatch accumulation, async checkpointing, watchdog, restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--mca]

At ~100M params on CPU this takes a while; --tiny trains a 1-minute
version with identical plumbing.
"""
import argparse

import jax

from repro.configs import get_config
from repro.core.policy import MCAConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import adamw
from repro.train import Trainer, TrainerConfig
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--mca", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    mca = MCAConfig(enabled=args.mca, alpha=0.4, block=64,
                    sites=("v_proj",))
    if args.tiny:
        cfg = get_config("starcoder2-3b", mca=mca).replace(
            n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
            d_ff=256, vocab_size=1024, dtype="float32", attn_chunk=64,
            logits_chunk=64)
        seq, batch, n_micro = 128, 8, 1
        steps = min(args.steps, 60)
    else:
        # ~100M-param decoder (GQA + RoPE + SwiGLU), bf16, remat+scan
        cfg = get_config("starcoder2-3b", mca=mca).replace(
            n_layers=10, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
            d_ff=2048, vocab_size=32000, dtype="float32")
        seq, batch, n_micro = 512, 8, 2
        steps = args.steps

    model = build_model(cfg)
    n_params = sum(
        p.size for p in jax.tree.leaves(
            jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"model: {cfg.name} modified, {n_params / 1e6:.1f}M params, "
          f"seq {seq}, batch {batch}, mca={'on' if args.mca else 'off'}")

    data = SyntheticLM(cfg.vocab_size, seq, batch, seed=0)
    opt_cfg = adamw.AdamWConfig(
        lr=3e-4, schedule=adamw.cosine_schedule(warmup=20, total=steps))
    # no donation: the Trainer's finite-check skip/rollback path reuses
    # pre-step params/opt_state, which donation would free on device
    step = jax.jit(make_train_step(model, opt_cfg, n_micro=n_micro))
    trainer = Trainer(model, opt_cfg, data, step,
                      TrainerConfig(total_steps=steps,
                                    ckpt_dir=args.ckpt_dir,
                                    ckpt_every=100, log_every=10))
    out = trainer.run()
    losses = [h["loss"] for h in out["history"]]
    print(f"steps/s {out['steps'] / out['wall_s']:.2f}  "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    import logging
    logging.basicConfig(level=logging.INFO)
    main()
