"""Example: reproduce one multi-pod dry-run cell programmatically.

Lowers + compiles qwen3-32b train_4k on the 2x16x16 (512-chip) production
mesh using placeholder devices, then prints the memory / cost / collective
analysis — the exact artifact EXPERIMENTS.md §Dry-run is built from.

Run:  PYTHONPATH=src python examples/multipod_dryrun.py
(takes a few minutes: it compiles a 512-way SPMD program on CPU)
"""
# XLA device-count override MUST precede any jax import
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from repro.launch.dryrun import analyze, lower_cell, roofline_terms

lowered, compiled, meta = lower_cell("qwen3-32b", "train_4k",
                                     multi_pod=True, mca=False)
result = analyze(compiled, meta, mesh_devices=512)

print(f"compile time      : {meta['compile_s']:.1f}s")
print(f"per-device temp   : {result.get('temp_size_in_bytes', 0) / 1e9:.2f} GB")
print(f"HLO flops (raw)   : {result.get('flops', 0):.3e}")
print("collectives       :")
for kind, st in result["collectives"].items():
    if isinstance(st, dict) and st["count"]:
        print(f"  {kind:20s} x{st['count']:4d}  {st['bytes'] / 1e9:.2f} GB")
print(f"roofline terms    : {roofline_terms(result)}")
