"""Quickstart: Monte-Carlo Attention in 60 seconds.

1. Approximate a matmul with the MCA block-sampling estimator.
2. Drive per-token precision from an attention matrix (Eq. 9).
3. Run a full transformer forward with MCA enabled and read the paper's
   FLOPs-reduction metric.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (MCAConfig, amm, mca_project, flops_reduction,
                        schedule)

key = jax.random.PRNGKey(0)

# --- 1. the Drineas-Kannan-Mahoney estimator at block granularity --------
kx, kw, ks = jax.random.split(key, 3)
x = jax.random.normal(kx, (64, 512))
w = jax.random.normal(kw, (512, 128)) / jnp.sqrt(512.0)

probs = amm.block_probs(w, block=128)          # Eq. 6, cached per layer
idx, inv_rp = amm.draw_block_samples(ks, probs, r=2)
approx = amm.sampled_matmul(x, w, idx, inv_rp, block=128)
exact = x @ w
rel = jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact)
print(f"[1] 2-of-4 block sample: relative error {float(rel):.3f} "
      f"(unbiased; shrinks as 1/sqrt(r))")

# --- 2. attention-driven sample schedule ---------------------------------
attn = jax.nn.softmax(jax.random.normal(key, (64, 64)) * 3.0, axis=-1)
colmax = jnp.max(attn, axis=0)                 # importance per key
r_cols = schedule.r_cols_from_attention(colmax, n=64, alpha=0.2, d=512)
print(f"[2] per-token column budgets: min={float(r_cols.min()):.0f} "
      f"max={float(r_cols.max()):.0f} of d=512")

# --- 3. drop-in MCA projection -------------------------------------------
cfg = MCAConfig(enabled=True, alpha=0.2, block=128, sites=("v_proj",))
y, stats = mca_project(key, x, w, colmax, seq_len=64, cfg=cfg,
                       site="v_proj")
print(f"[3] mca_project: FLOPs reduction "
      f"{float(flops_reduction(stats)):.2f}x on the encoding "
      f"(paper Table 1 metric)")

# --- 4. whole-model: enable MCA on a reduced architecture ----------------
from repro.configs import get_config
from repro.models import build_model, reduced

cfg_model = reduced(get_config("starcoder2-3b"),
                    mca=MCAConfig(enabled=True, alpha=0.4, block=16,
                                  sites=("v_proj",)))
model = build_model(cfg_model)
params = model.init(jax.random.PRNGKey(1))
batch = {
    "tokens": jax.random.randint(key, (2, 64), 0, cfg_model.vocab_size),
    "labels": jax.random.randint(key, (2, 64), 0, cfg_model.vocab_size),
}
loss, metrics = jax.jit(lambda p, b, k: model.loss(p, b, k))(
    params, batch, jax.random.PRNGKey(2))
print(f"[4] starcoder2 (reduced) with MCA: loss {float(loss):.3f}, "
      f"attention-encoding FLOPs reduction "
      f"{float(metrics['mca_exact_flops'] / metrics['mca_flops']):.2f}x")
