"""Unit tests for the repro.obs metrics layer (registry, scoping, sink)."""
import json
import math
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs


class TestRegistry:
    def test_counter_gauge_histogram(self):
        reg = obs.Registry()
        reg.counter("c").inc()
        reg.counter("c").inc(2.5)
        assert reg.counter("c").value == 3.5
        reg.gauge("g").set(7)
        assert reg.gauge("g").value == 7.0
        h = reg.histogram("h")
        for v in (1.0, 2.0, 3.0, 4.0):
            h.observe(v)
        assert h.count == 4
        assert h.total == 10.0
        assert h.min == 1.0 and h.max == 4.0
        assert h.mean == 2.5

    def test_jax_scalars_coerced(self):
        reg = obs.Registry()
        reg.counter("c").inc(jnp.asarray(2.0))
        reg.gauge("g").set(np.float32(1.5))
        snap = reg.snapshot()
        assert snap["counters"]["c"] == 2.0
        assert snap["gauges"]["g"] == 1.5
        json.dumps(snap)                       # fully serializable

    def test_timer_records_elapsed(self):
        reg = obs.Registry()
        with reg.timer("t"):
            pass
        h = reg.histogram("t")
        assert h.count == 1
        assert 0.0 <= h.total < 1.0

    def test_histogram_percentiles(self):
        h = obs.Histogram()
        for v in range(100):
            h.observe(float(v))
        assert abs(h.percentile(50) - 50.0) <= 2.0
        assert h.percentile(95) >= 90.0
        s = h.summary()
        assert s["count"] == 100 and not math.isnan(s["p50"])

    def test_snapshot_empty_registry(self):
        snap = obs.Registry().snapshot()
        assert snap == {"counters": {}, "gauges": {}, "histograms": {}}


class TestScoping:
    def test_scoped_isolates_from_global(self):
        g = obs.get_registry()
        before = g.counter("scope.test").value
        with obs.scoped() as reg:
            assert obs.get_registry() is reg
            obs.get_registry().counter("scope.test").inc()
            assert reg.counter("scope.test").value == 1
        assert g.counter("scope.test").value == before
        assert obs.get_registry() is g

    def test_scoped_nesting(self):
        with obs.scoped() as outer:
            with obs.scoped() as inner:
                obs.get_registry().counter("n").inc()
            assert inner.counter("n").value == 1
            assert outer.counter("n").value == 0

    def test_scopes_are_thread_local(self):
        seen = {}

        def worker():
            seen["reg"] = obs.get_registry()

        with obs.scoped() as reg:
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        assert seen["reg"] is not reg      # other thread saw the global


class TestSink:
    def test_write_and_read(self, tmp_path):
        sink = obs.JsonlSink(str(tmp_path / "m.jsonl"))
        sink.write("train_step", step=1, loss=2.5,
                   tier_hist=jnp.asarray([1.0, 2.0]))
        recs = obs.read_jsonl(str(tmp_path / "m.jsonl"))
        assert len(recs) == 1
        assert recs[0]["kind"] == "train_step"
        assert recs[0]["loss"] == 2.5
        assert recs[0]["tier_hist"] == [1.0, 2.0]
        assert "ts" in recs[0]

    def test_write_snapshot(self, tmp_path):
        sink = obs.JsonlSink(str(tmp_path / "m.jsonl"))
        with obs.scoped() as reg:
            reg.counter("x").inc(3)
            sink.write_snapshot(reg)
        recs = obs.read_jsonl(str(tmp_path / "m.jsonl"))
        assert recs[0]["kind"] == "snapshot"
        assert recs[0]["counters"]["x"] == 3.0


class TestTrace:
    def test_trace_and_annotate_are_noop_safe(self):
        with obs.trace("unit.test"):
            x = 1 + 1

        @obs.annotate("unit.fn")
        def fn(a):
            return a * 2

        assert x == 2 and fn(3) == 6


class TestTrainerIntegration:
    def test_trainer_surfaces_mca_stats(self, tmp_path):
        """A short MCA-enabled training run must land per-step flops
        reduction + tier occupancy in the obs registry and the JSONL sink."""
        import jax
        from repro.configs import get_config
        from repro.core.policy import MCAConfig
        from repro.data import SyntheticLM
        from repro.models import build_model, reduced
        from repro.optim import adamw
        from repro.train.step import make_train_step
        from repro.train.trainer import Trainer, TrainerConfig

        cfg = reduced(get_config("starcoder2-3b"), n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
                      vocab_size=128,
                      mca=MCAConfig(enabled=True, alpha=0.4, block=16,
                                    sites=("v_proj",)))
        model = build_model(cfg)
        data = SyntheticLM(cfg.vocab_size, 16, 2, seed=0)
        step = jax.jit(make_train_step(model, adamw.AdamWConfig(lr=1e-3)))
        metrics_path = str(tmp_path / "metrics.jsonl")
        tcfg = TrainerConfig(total_steps=3, log_every=100,
                             metrics_path=metrics_path)
        with obs.scoped() as reg:
            res = Trainer(model, adamw.AdamWConfig(lr=1e-3), data, step,
                          tcfg).run()
            snap = reg.snapshot()
        assert res["steps"] == 3
        assert snap["counters"]["train.steps"] == 3
        assert snap["histograms"]["train.step_seconds"]["count"] == 3
        assert snap["gauges"]["train.flops_reduction"] > 1.0
        occ = [v for k, v in snap["counters"].items()
               if k.startswith("train.tier_occupancy.t")]
        assert occ and sum(occ) > 0
        # per-step record + final snapshot in the sink
        recs = obs.read_jsonl(metrics_path)
        steps = [r for r in recs if r["kind"] == "train_step"]
        assert len(steps) == 3
        assert steps[-1]["flops_reduction"] > 1.0
        assert len(steps[-1]["tier_hist"]) == cfg.mca.n_tiers
        assert recs[-1]["kind"] == "snapshot"
        # trainer history mirrors the records
        assert res["history"][-1]["flops_reduction"] > 1.0


class TestTracing:
    def test_disabled_is_complete_noop(self):
        """Satellite: with tracing off, span machinery must not touch the
        registry, must not allocate per call, and must not raise."""
        assert not obs.tracing_enabled()
        with obs.scoped() as reg:
            with obs.span("x", cat="c", extra=1):
                pass
            obs.record_span("y", 0.0, 1.0, cat="c")
            obs.mark("z", cat="c")
        assert reg.spans() == []
        # disabled span() hands back one shared null context
        assert obs.span("a") is obs.span("b")

    def test_noop_inside_jit(self):
        """Span calls inside jit-traced Python: no exceptions, no registry
        writes while disabled (trace-time Python runs once per compile)."""
        import jax

        with obs.scoped() as reg:
            @jax.jit
            def f(x):
                with obs.span("traced", cat="jit"):
                    obs.mark("inside", cat="jit")
                    return x * 2

            assert int(f(jnp.asarray(3))) == 6
            assert int(f(jnp.asarray(4))) == 8     # cached executable too
        assert reg.spans() == []

    def test_tracing_ctx_restores_prior_state(self):
        assert not obs.tracing_enabled()
        with obs.tracing():
            assert obs.tracing_enabled()
            with obs.tracing(False):
                assert not obs.tracing_enabled()
            assert obs.tracing_enabled()
        assert not obs.tracing_enabled()

    def test_span_records_interval_args_and_error(self):
        with obs.scoped() as reg, obs.tracing():
            with obs.span("ok", cat="t", track="tr", k=1):
                pass
            with pytest.raises(ValueError):
                with obs.span("boom", cat="t"):
                    raise ValueError("x")
            obs.record_span("manual", 10.0, 10.5, cat="t", args={"a": 2})
            obs.mark("instant", cat="t", track="tr")
        spans = reg.spans()
        by_name = {s["name"]: s for s in spans}
        assert set(by_name) == {"ok", "boom", "manual", "instant"}
        assert by_name["ok"]["track"] == "tr"
        assert by_name["ok"]["args"] == {"k": 1}
        assert by_name["ok"]["dur"] >= 0.0
        assert by_name["boom"]["args"]["error"] == "ValueError"
        assert by_name["boom"]["track"] == "t"     # falls back to cat
        assert by_name["manual"]["dur"] == 0.5
        assert by_name["instant"]["dur"] == 0.0

    def test_span_deque_bounded_and_drop_counted(self, monkeypatch):
        monkeypatch.setattr(obs.Registry, "MAX_SPANS", 4)
        reg = obs.Registry()
        with obs.scoped(reg), obs.tracing():
            for i in range(7):
                obs.mark(f"s{i}", cat="t")
        assert len(reg.spans()) == 4
        assert reg.spans_dropped == 3
        assert reg.spans()[0]["name"] == "s3"      # oldest evicted first

    def test_export_chrome_trace(self, tmp_path):
        with obs.scoped() as reg, obs.tracing():
            obs.record_span("a", 5.0, 5.25, cat="c1", track="t1",
                            args={"k": 1})
            obs.record_span("b", 5.1, 5.2, cat="c2", track="t2")
        path = str(tmp_path / "trace.json")
        trace = obs.export_chrome_trace(path, registry=reg)
        on_disk = json.loads(open(path).read())
        assert on_disk == json.loads(json.dumps(trace))
        evs = trace["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        xs = [e for e in evs if e["ph"] == "X"]
        assert {m["args"]["name"] for m in meta} == {"repro", "t1", "t2"}
        assert len(xs) == 2
        a = next(e for e in xs if e["name"] == "a")
        b = next(e for e in xs if e["name"] == "b")
        assert a["ts"] == 0.0 and a["dur"] == 250_000.0     # rebased, us
        assert b["ts"] == 100_000.0 and b["dur"] == 100_000.0
        assert a["tid"] != b["tid"]                # one timeline per track
        assert a["args"] == {"k": 1}

    def test_export_empty_registry(self, tmp_path):
        trace = obs.export_chrome_trace(str(tmp_path / "e.json"),
                                        registry=obs.Registry())
        assert [e["ph"] for e in trace["traceEvents"]] == ["M"]


class TestRequestChains:
    """Acceptance: one complete queue->prefill->decode->finish chain per
    request, from each batcher, exported as valid Chrome-trace JSON."""

    @pytest.fixture(scope="class")
    def serve_setup(self):
        import jax
        from repro.configs import get_config
        from repro.models import build_model, reduced

        cfg = reduced(get_config("starcoder2-3b"), n_layers=2,
                      vocab_size=128)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return cfg, model, params

    def _serve_traced(self, serve_setup, batcher_cls, **kw):
        from repro.serve import Engine, Request

        cfg, model, params = serve_setup
        eng = Engine(model, params, batch_size=2, max_len=64)
        rng = np.random.default_rng(11)
        prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (4, 9, 6)]
        with obs.scoped() as reg, obs.tracing():
            b = batcher_cls(eng, **kw)
            for i, p in enumerate(prompts):
                assert b.submit(Request(uid=i, prompt=p,
                                        max_new=4)) == "queued"
            b.run()
        assert all(b.status[i] == "ok" for i in range(len(prompts)))
        return reg, len(prompts)

    def _check_chains(self, reg, n_req, cat):
        chains = {}
        for s in reg.spans():
            if s["track"].startswith(f"{cat}/req"):
                chains.setdefault(s["track"], []).append(s)
        assert len(chains) == n_req, sorted(chains)
        for track, spans in chains.items():
            names = [s["name"] for s in spans]
            assert names[0] == "queue", (track, names)
            assert names[1] == "prefill", (track, names)
            assert names[-1] == "finish", (track, names)
            decodes = names[2:-1]
            assert decodes and set(decodes) == {"decode"}, (track, names)
            # same perf_counter clock: phases are ordered in time
            end = [s["ts"] + s["dur"] for s in spans]
            start = [s["ts"] for s in spans]
            assert all(start[i + 1] >= end[i] - 1e-3
                       for i in range(len(spans) - 1)), (track, names)
            assert spans[-1]["args"]["status"] == "ok"

    @pytest.mark.parametrize("which", ["wave", "per_slot"])
    def test_batcher_emits_complete_chains(self, serve_setup, which,
                                           tmp_path):
        from repro.serve import ContinuousBatcher, SlotBatcher

        cls, cat, kw = {
            "wave": (ContinuousBatcher, "serve.wave", {}),
            "per_slot": (SlotBatcher, "serve.per_slot",
                         {"check_every": 4}),
        }[which]
        reg, n_req = self._serve_traced(serve_setup, cls, **kw)
        self._check_chains(reg, n_req, cat)
        # and the export round-trips as valid Chrome-trace JSON
        path = str(tmp_path / f"{which}.json")
        obs.export_chrome_trace(path, registry=reg)
        trace = json.loads(open(path).read())
        xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
        assert len(xs) == len(reg.spans())
        assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)

    def test_no_spans_when_tracing_disabled(self, serve_setup):
        """Serving with tracing off must leave the registry span-free."""
        from repro.serve import SlotBatcher

        reg, _ = self._serve_traced_disabled(serve_setup, SlotBatcher,
                                             check_every=4)
        assert reg.spans() == []

    def _serve_traced_disabled(self, serve_setup, batcher_cls, **kw):
        from repro.serve import Engine, Request

        cfg, model, params = serve_setup
        eng = Engine(model, params, batch_size=2, max_len=64)
        rng = np.random.default_rng(12)
        prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (4, 6)]
        with obs.scoped() as reg:
            b = batcher_cls(eng, **kw)
            for i, p in enumerate(prompts):
                b.submit(Request(uid=i, prompt=p, max_new=3))
            b.run()
        return reg, len(prompts)


class TestSinkCrashSafety:
    def test_write_flushes_immediately(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        sink = obs.JsonlSink(path)
        sink.write("a", i=1)
        # visible to a second reader BEFORE close (per-write flush)
        assert obs.read_jsonl(path)[0]["i"] == 1
        sink.close()

    def test_closed_sink_raises(self, tmp_path):
        sink = obs.JsonlSink(str(tmp_path / "m.jsonl"))
        sink.close()
        with pytest.raises(ValueError, match="closed"):
            sink.write("late")
        sink.close()                               # idempotent

    def test_context_manager(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        with obs.JsonlSink(path) as sink:
            sink.write("a", i=1)
        assert obs.read_jsonl(path)[0]["i"] == 1

    def test_threaded_writes_interleave_whole_lines(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        sink = obs.JsonlSink(path)

        def worker(tid):
            for i in range(50):
                sink.write("w", tid=tid, i=i, pad="x" * 64)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        sink.close()
        recs = obs.read_jsonl(path)
        assert len(recs) == 200
        seen = {(r["tid"], r["i"]) for r in recs}
        assert len(seen) == 200                    # nothing torn or lost

    def test_killed_writer_leaves_only_complete_lines(self, tmp_path):
        """Regression: SIGKILL mid-stream must not leave partial JSON
        (each record is one flushed write; nothing buffers across
        records)."""
        import os
        import subprocess
        import sys
        import time

        path = str(tmp_path / "kill.jsonl")
        script = (
            "from repro.obs import JsonlSink\n"
            f"s = JsonlSink({path!r})\n"
            "i = 0\n"
            "while True:\n"
            "    s.write('spin', i=i, pad='x' * 200)\n"
            "    i += 1\n"
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        proc = subprocess.Popen([sys.executable, "-c", script], env=env)
        try:
            deadline = time.time() + 60
            while time.time() < deadline:
                if os.path.exists(path) and os.path.getsize(path) > 8192:
                    break
                time.sleep(0.05)
            else:
                raise AssertionError("writer produced no output")
        finally:
            proc.kill()
            proc.wait()
        lines = open(path).read().splitlines()
        assert len(lines) >= 10
        for ln in lines:
            rec = json.loads(ln)                   # every line is whole
            assert rec["kind"] == "spin"


class TestScopedThreads:
    def test_nested_scopes_do_not_leak_across_threads(self):
        """Satellite: concurrent threads each nest scoped() registries;
        counts must stay per-thread and the global must be untouched."""
        g = obs.get_registry()
        before = g.counter("thread.test").value
        errors = []
        start = threading.Barrier(6)

        def worker(i):
            try:
                start.wait(timeout=30)
                for _ in range(20):
                    with obs.scoped() as outer:
                        assert obs.get_registry() is outer
                        outer.counter("thread.test").inc(i)
                        with obs.scoped() as inner:
                            assert obs.get_registry() is inner
                            inner.counter("thread.test").inc(1000)
                        assert obs.get_registry() is outer
                        assert outer.counter("thread.test").value == i
                        assert inner.counter("thread.test").value == 1000
            except Exception as e:                 # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i + 1,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        assert g.counter("thread.test").value == before
        assert obs.get_registry() is g


class TestAggregate:
    def test_world1_psum_equals_local(self):
        """Single process, single device: aggregate='psum' must be the
        plain local snapshot."""
        with obs.scoped() as reg:
            reg.counter("a").inc(3)
            h = reg.histogram("h")
            h.observe(2.0)
            h.observe(4.0)
            local = reg.snapshot()
            agg = obs.snapshot(aggregate="psum")
        assert agg["counters"]["a"] == local["counters"]["a"] == 3.0
        assert agg["histograms"]["h"]["count"] == 2
        assert agg["histograms"]["h"]["sum"] == 6.0
        assert agg["histograms"]["h"]["min"] == 2.0
        assert agg["histograms"]["h"]["max"] == 4.0

    def test_default_is_local(self):
        with obs.scoped() as reg:
            reg.counter("b").inc(2)
            snap = obs.snapshot()
        assert snap == reg.snapshot()

    def test_unknown_aggregate_rejected(self):
        with pytest.raises(ValueError, match="aggregate"):
            obs.snapshot(aggregate="allgather")

    def test_summary_has_p99(self):
        h = obs.Histogram()
        for v in range(200):
            h.observe(float(v))
        s = h.summary()
        assert s["p99"] >= s["p95"] >= s["p50"]
        assert s["p99"] >= 190.0
