"""Unit tests for the repro.obs metrics layer (registry, scoping, sink)."""
import json
import math
import threading

import jax.numpy as jnp
import numpy as np

from repro import obs


class TestRegistry:
    def test_counter_gauge_histogram(self):
        reg = obs.Registry()
        reg.counter("c").inc()
        reg.counter("c").inc(2.5)
        assert reg.counter("c").value == 3.5
        reg.gauge("g").set(7)
        assert reg.gauge("g").value == 7.0
        h = reg.histogram("h")
        for v in (1.0, 2.0, 3.0, 4.0):
            h.observe(v)
        assert h.count == 4
        assert h.total == 10.0
        assert h.min == 1.0 and h.max == 4.0
        assert h.mean == 2.5

    def test_jax_scalars_coerced(self):
        reg = obs.Registry()
        reg.counter("c").inc(jnp.asarray(2.0))
        reg.gauge("g").set(np.float32(1.5))
        snap = reg.snapshot()
        assert snap["counters"]["c"] == 2.0
        assert snap["gauges"]["g"] == 1.5
        json.dumps(snap)                       # fully serializable

    def test_timer_records_elapsed(self):
        reg = obs.Registry()
        with reg.timer("t"):
            pass
        h = reg.histogram("t")
        assert h.count == 1
        assert 0.0 <= h.total < 1.0

    def test_histogram_percentiles(self):
        h = obs.Histogram()
        for v in range(100):
            h.observe(float(v))
        assert abs(h.percentile(50) - 50.0) <= 2.0
        assert h.percentile(95) >= 90.0
        s = h.summary()
        assert s["count"] == 100 and not math.isnan(s["p50"])

    def test_snapshot_empty_registry(self):
        snap = obs.Registry().snapshot()
        assert snap == {"counters": {}, "gauges": {}, "histograms": {}}


class TestScoping:
    def test_scoped_isolates_from_global(self):
        g = obs.get_registry()
        before = g.counter("scope.test").value
        with obs.scoped() as reg:
            assert obs.get_registry() is reg
            obs.get_registry().counter("scope.test").inc()
            assert reg.counter("scope.test").value == 1
        assert g.counter("scope.test").value == before
        assert obs.get_registry() is g

    def test_scoped_nesting(self):
        with obs.scoped() as outer:
            with obs.scoped() as inner:
                obs.get_registry().counter("n").inc()
            assert inner.counter("n").value == 1
            assert outer.counter("n").value == 0

    def test_scopes_are_thread_local(self):
        seen = {}

        def worker():
            seen["reg"] = obs.get_registry()

        with obs.scoped() as reg:
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        assert seen["reg"] is not reg      # other thread saw the global


class TestSink:
    def test_write_and_read(self, tmp_path):
        sink = obs.JsonlSink(str(tmp_path / "m.jsonl"))
        sink.write("train_step", step=1, loss=2.5,
                   tier_hist=jnp.asarray([1.0, 2.0]))
        recs = obs.read_jsonl(str(tmp_path / "m.jsonl"))
        assert len(recs) == 1
        assert recs[0]["kind"] == "train_step"
        assert recs[0]["loss"] == 2.5
        assert recs[0]["tier_hist"] == [1.0, 2.0]
        assert "ts" in recs[0]

    def test_write_snapshot(self, tmp_path):
        sink = obs.JsonlSink(str(tmp_path / "m.jsonl"))
        with obs.scoped() as reg:
            reg.counter("x").inc(3)
            sink.write_snapshot(reg)
        recs = obs.read_jsonl(str(tmp_path / "m.jsonl"))
        assert recs[0]["kind"] == "snapshot"
        assert recs[0]["counters"]["x"] == 3.0


class TestTrace:
    def test_trace_and_annotate_are_noop_safe(self):
        with obs.trace("unit.test"):
            x = 1 + 1

        @obs.annotate("unit.fn")
        def fn(a):
            return a * 2

        assert x == 2 and fn(3) == 6


class TestTrainerIntegration:
    def test_trainer_surfaces_mca_stats(self, tmp_path):
        """A short MCA-enabled training run must land per-step flops
        reduction + tier occupancy in the obs registry and the JSONL sink."""
        import jax
        from repro.configs import get_config
        from repro.core.policy import MCAConfig
        from repro.data import SyntheticLM
        from repro.models import build_model, reduced
        from repro.optim import adamw
        from repro.train.step import make_train_step
        from repro.train.trainer import Trainer, TrainerConfig

        cfg = reduced(get_config("starcoder2-3b"), n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
                      vocab_size=128,
                      mca=MCAConfig(enabled=True, alpha=0.4, block=16,
                                    sites=("v_proj",)))
        model = build_model(cfg)
        data = SyntheticLM(cfg.vocab_size, 16, 2, seed=0)
        step = jax.jit(make_train_step(model, adamw.AdamWConfig(lr=1e-3)))
        metrics_path = str(tmp_path / "metrics.jsonl")
        tcfg = TrainerConfig(total_steps=3, log_every=100,
                             metrics_path=metrics_path)
        with obs.scoped() as reg:
            res = Trainer(model, adamw.AdamWConfig(lr=1e-3), data, step,
                          tcfg).run()
            snap = reg.snapshot()
        assert res["steps"] == 3
        assert snap["counters"]["train.steps"] == 3
        assert snap["histograms"]["train.step_seconds"]["count"] == 3
        assert snap["gauges"]["train.flops_reduction"] > 1.0
        occ = [v for k, v in snap["counters"].items()
               if k.startswith("train.tier_occupancy.t")]
        assert occ and sum(occ) > 0
        # per-step record + final snapshot in the sink
        recs = obs.read_jsonl(metrics_path)
        steps = [r for r in recs if r["kind"] == "train_step"]
        assert len(steps) == 3
        assert steps[-1]["flops_reduction"] > 1.0
        assert len(steps[-1]["tier_hist"]) == cfg.mca.n_tiers
        assert recs[-1]["kind"] == "snapshot"
        # trainer history mirrors the records
        assert res["history"][-1]["flops_reduction"] > 1.0
