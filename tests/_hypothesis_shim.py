"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface
used by this test suite (``given`` / ``settings`` / four strategies).

Installed by conftest.py as ``sys.modules["hypothesis"]`` ONLY when the
real package is unavailable (the CI container does not ship it).  Examples
are drawn from a per-test deterministic PRNG (seeded by the test's
qualified name), so runs are reproducible — matching the fixed-seed
policy the Monte-Carlo tests need.  There is no shrinking: a failing
example is reported with its drawn arguments and left to the reader.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

__version__ = "0.0-shim"


class _Strategy:
    def __init__(self, draw, label: str):
        self._draw = draw
        self._label = label

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self) -> str:
        return self._label


class _Strategies:
    """The ``hypothesis.strategies`` namespace (imported ``as st``)."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 31):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         f"floats({min_value}, {max_value})")

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5, "booleans()")

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))],
                         f"sampled_from({seq})")


strategies = _Strategies()

_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int | None = None, deadline=None, **_):
    def decorate(fn):
        fn._shim_settings = {"max_examples": max_examples}
        return fn
    return decorate


def given(**param_strategies):
    def decorate(fn):
        sig = inspect.signature(fn)
        passthrough = [p for p in sig.parameters.values()
                       if p.name not in param_strategies]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_shim_settings", {})
            n = cfg.get("max_examples") or _DEFAULT_MAX_EXAMPLES
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = {name: strat.draw(rng)
                         for name, strat in param_strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example #{i}: {drawn!r}") from exc

        # hide strategy-filled params so pytest doesn't treat them as
        # fixtures (hypothesis does the same)
        wrapper.__signature__ = sig.replace(parameters=passthrough)
        return wrapper
    return decorate


class HealthCheck:  # referenced by some hypothesis idioms; all no-ops
    all = staticmethod(lambda: ())
    too_slow = data_too_large = filter_too_much = None


def assume(condition: bool) -> bool:
    if not condition:
        raise AssertionError("assume() failed (shim has no rejection "
                             "sampling; restructure the strategy)")
    return True
