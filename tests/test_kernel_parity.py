"""Kernel ↔ reference parity (Pallas interpret mode on CPU).

Complements test_kernels.py's randomized allclose checks with the two
contractual properties the MCA pipeline relies on:

  exact mode    enumerating every block once with unit weights makes
                mca_matmul IDENTICAL to the dense product (and to
                kernels/ref.py), so the "exact tier" of the tiered
                dispatch is a true fallback, not an approximation;
  sampled mode  the kernel's Monte-Carlo error obeys the paper's Lemma-1
                bound E||err_row|| <= ||X[j]|| ||W||_F / sqrt(r).

On CPU the wrappers in kernels/ops.py run every Pallas body with
interpret=True; shapes here are chosen so the kernel path (not the jnp
fallback) is exercised: m % block_m == 0, d % block == 0, block >= 128.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import amm, error_bounds
from repro.kernels import attn_colmax, flash_attention, mca_matmul
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------- exact mode
@pytest.mark.parametrize("m,d,f,block", [
    (128, 512, 128, 128),
    (256, 256, 256, 128),
])
def test_mca_matmul_exact_mode_equals_dense(m, d, f, block):
    """idx = (0..K-1), inv_rp = 1: the estimator degenerates to the exact
    blocked matmul — must match X @ W to f32 accumulation precision."""
    kx, kw = jax.random.split(jax.random.PRNGKey(m + d), 2)
    x = jax.random.normal(kx, (m, d))
    w = jax.random.normal(kw, (d, f))
    k = d // block
    idx = jnp.arange(k, dtype=jnp.int32)
    inv_rp = jnp.ones((k,), jnp.float32)
    out = mca_matmul(x, w, idx, inv_rp, block=block)
    dense = jnp.dot(x, w, preferred_element_type=jnp.float32)
    # vs dense: accumulation ORDER differs (per-block partial sums), so this
    # is fp-tolerance equality, not bitwise
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)
    ref = kref.ref_mca_matmul_fixed(x, w, idx, inv_rp, block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_exact_equals_reference():
    """The flash kernel is exact (reordered, not approximated): out and lse
    must match the materialized-A oracle tightly in f32."""
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    b, h, s, dh = 2, 4, 128, 64
    q = jax.random.normal(kq, (b, h, s, dh))
    k = jax.random.normal(kk, (b, h, s, dh))
    v = jax.random.normal(kv, (b, h, s, dh))
    scale = dh ** -0.5
    for causal in (False, True):
        out, lse = flash_attention(q, k, v, scale=scale, causal=causal,
                                   block_q=64, block_k=64)
        ref_out, ref_lse = kref.ref_attention(q, k, v, scale=scale,
                                              causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(ref_lse),
                                   rtol=1e-5, atol=1e-5)


def test_attn_colmax_exact_equals_reference():
    key = jax.random.PRNGKey(1)
    kq, kk = jax.random.split(key)
    b, h, s, dh = 1, 2, 128, 64
    q = jax.random.normal(kq, (b, h, s, dh))
    k = jax.random.normal(kk, (b, h, s, dh))
    scale = dh ** -0.5
    for causal in (False, True):
        _, lse = flash_attention(q, k, jnp.zeros_like(k), scale=scale,
                                 causal=causal, block_q=64, block_k=64)
        cm = attn_colmax(q, k, lse, scale=scale, causal=causal,
                         block_q=64, block_k=64, reduce_heads=False)
        ref = kref.ref_colmax(q, k, lse, scale=scale, causal=causal)
        np.testing.assert_allclose(np.asarray(cm), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- sampled mode
@pytest.mark.parametrize("r", [2, 4, 8])
def test_mca_matmul_sampled_error_within_lemma1_bound(r):
    """Empirical E||err_row|| from the KERNEL path stays under the paper's
    Lemma-1 bound (Eq. 7).  64 fixed-seed trials estimate the expectation;
    25% slack covers MC noise on the mean (same margin as test_core_policy)."""
    m, d, f, block = 128, 512, 128, 128
    kx, kw = jax.random.split(jax.random.PRNGKey(42), 2)
    x = jax.random.normal(kx, (m, d))
    w = jax.random.normal(kw, (d, f))
    probs = amm.block_probs(w, block)
    exact = jnp.dot(x, w, preferred_element_type=jnp.float32)

    @jax.jit
    def one(key):
        idx, inv_rp = amm.draw_block_samples(key, probs, r)
        est = mca_matmul(x, w, idx, inv_rp, block=block)
        return jnp.linalg.norm(est - exact, axis=-1)         # [m]

    keys = jax.random.split(jax.random.PRNGKey(7), 64)
    errs = jnp.stack([one(k) for k in keys])                 # [T, m]
    mean_err = jnp.mean(errs, axis=0)                        # per-row E||err||
    bound = error_bounds.lemma1_bound(
        jnp.linalg.norm(x, axis=-1), error_bounds.w_fro(w),
        jnp.full((m,), r, jnp.float32))
    assert bool(jnp.all(mean_err <= 1.25 * bound)), (
        float(jnp.max(mean_err / bound)))


def test_sampled_error_shrinks_with_r():
    """Doubling r must not increase the empirical error (1/sqrt(r) decay)."""
    m, d, f, block = 128, 512, 128, 128
    kx, kw = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(kx, (m, d))
    w = jax.random.normal(kw, (d, f))
    probs = amm.block_probs(w, block)
    exact = jnp.dot(x, w, preferred_element_type=jnp.float32)

    def mean_err(r):
        @jax.jit
        def one(k):
            return jnp.linalg.norm(
                mca_matmul(x, w, *amm.draw_block_samples(k, probs, r),
                           block=block) - exact)
        keys = jax.random.split(jax.random.PRNGKey(11), 32)
        return float(jnp.mean(jnp.stack([one(k) for k in keys])))

    e1, e2, e4 = mean_err(1), mean_err(2), mean_err(4)
    assert e2 < e1 and e4 < e2, (e1, e2, e4)
