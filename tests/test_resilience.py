"""Fault-injection + graceful-degradation suite (repro.resilience).

Drives every canonical injection point end-to-end — NaN logits, slow
steps, checkpoint write failures, corrupt checkpoints, data stalls,
oversized prompts, queue overflow — and asserts the system *recovers
without a process crash*, that the exact-attention fallback wave is
token-identical to an MCA-off engine, and that every recovery event is
visible as a ``resilience.*`` counter in an ``obs.scoped()`` snapshot.
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, resilience
from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.core import amm
from repro.data import Prefetcher, SyntheticLM
from repro.models import build_model, reduced
from repro.optim import adamw
from repro.resilience import Fault, FaultInjected, NonFiniteError
from repro.serve import ContinuousBatcher, Engine, Request
from repro.train import Trainer, TrainerConfig, TrainingDivergedError

jax.config.update("jax_platform_name", "cpu")


# ====================================================== injection core ==
class TestInjection:
    def test_noop_without_chaos(self):
        assert resilience.inject("serve.prefill", 42) == 42
        assert not resilience.active()

    def test_canonical_points_registered(self):
        assert set(resilience.CANONICAL_POINTS) <= set(resilience.points())

    def test_raise_mode_and_counter(self):
        with obs.scoped() as reg:
            with resilience.chaos(Fault("ckpt.write", mode="raise")):
                with pytest.raises(FaultInjected):
                    resilience.inject("ckpt.write")
            snap = reg.snapshot()
        assert snap["counters"]["resilience.injected.ckpt.write"] == 1

    def test_delay_mode(self):
        with resilience.chaos(Fault("data.batch", mode="delay",
                                    delay_s=0.05)):
            t0 = time.perf_counter()
            out = resilience.inject("data.batch", "v")
            assert out == "v"
            assert time.perf_counter() - t0 >= 0.05

    def test_corrupt_mode_nan_poisons(self):
        with resilience.chaos(Fault("serve.prefill", mode="corrupt")):
            out = resilience.inject("serve.prefill",
                                    np.ones((4, 4), np.float32))
        assert np.isnan(out).any()
        assert resilience.inject("serve.prefill", 1.0) == 1.0  # plan popped

    def test_after_and_times_windows(self):
        with resilience.chaos(Fault("train.loss", mode="corrupt",
                                    after=1, times=2)):
            hits = [resilience.inject("train.loss", 1.0) for _ in range(5)]
        finite = [np.isfinite(h) for h in hits]
        assert finite == [True, False, False, True, True]

    def test_deterministic_seeded_probability(self):
        def run():
            with resilience.chaos(Fault("train.loss", mode="corrupt",
                                        times=None, p=0.5, seed=3)):
                return [np.isfinite(resilience.inject("train.loss", 1.0))
                        for _ in range(20)]
        a, b = run(), run()
        assert a == b                    # seeded => identical firing pattern
        assert any(a) and not all(a)     # coin actually mixes

    def test_chaos_restores_on_exception(self):
        with pytest.raises(RuntimeError):
            with resilience.chaos(Fault("train.step", mode="raise")):
                raise RuntimeError("boom")
        assert not resilience.active()


# ======================================================= numeric guards ==
class TestGuards:
    def test_is_finite(self):
        assert resilience.is_finite(1.0)
        assert not resilience.is_finite(float("nan"))
        assert not resilience.is_finite(np.asarray([1.0, np.inf]))
        assert resilience.is_finite(np.asarray([1, 2], np.int32))

    def test_check_finite_raises(self):
        with pytest.raises(NonFiniteError, match="wave logits"):
            resilience.check_finite(np.asarray([np.nan]), "wave logits")

    def test_amm_probs_survive_nan_norms(self):
        """Corrupted block norms must still yield a valid distribution."""
        w = jnp.ones((64, 8))
        with resilience.chaos(Fault("amm.probs", mode="corrupt")):
            p = amm.block_probs(w, block=16)
        p = np.asarray(p)
        assert np.isfinite(p).all() and p.min() >= 0
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-6)

    def test_amm_probs_all_zero_weights_uniform(self):
        p = np.asarray(amm.block_probs(jnp.zeros((64, 8)), block=16))
        np.testing.assert_allclose(p, 0.25, rtol=1e-5)

    def test_amm_estimator_weights_finite_on_degenerate_p(self):
        probs = jnp.asarray([0.0, float("nan"), 1.0, 0.0])
        idx, inv_rp = amm.draw_block_samples(jax.random.PRNGKey(0),
                                             probs, r=8)
        assert np.isfinite(np.asarray(inv_rp)).all()


# ==================================================== checkpoint layer ==
def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}


def _corrupt_npz(step_dir):
    """Flip payload bytes mid-file (zip headers live at start/end)."""
    path = os.path.join(step_dir, "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 2)
        f.write(b"\xff" * 8)


class TestCheckpointIntegrity:
    def test_corrupt_array_detected(self, tmp_path):
        d = ckpt.save(str(tmp_path), 1, _tree())
        _corrupt_npz(d)
        with pytest.raises(ckpt.CheckpointCorruptError):
            ckpt.restore(str(tmp_path), 1, jax.eval_shape(_tree))

    def test_restore_latest_valid_falls_back(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        d2 = ckpt.save(str(tmp_path), 2, tree)
        _corrupt_npz(d2)
        with obs.scoped() as reg:
            step, out = ckpt.restore_latest_valid(str(tmp_path),
                                                  jax.eval_shape(_tree))
            snap = reg.snapshot()
        assert step == 1
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert snap["counters"]["resilience.ckpt.corrupt_skipped"] == 1

    def test_latest_step_skips_torn_dirs(self, tmp_path):
        ckpt.save(str(tmp_path), 3, _tree())
        os.makedirs(tmp_path / "step_00000099")          # no manifest
        assert ckpt.latest_step(str(tmp_path)) == 3

    def test_stale_tmp_cleanup(self, tmp_path):
        os.makedirs(tmp_path / "step_00000007.tmp")
        with obs.scoped() as reg:
            assert ckpt.cleanup_stale_tmp(str(tmp_path)) == 1
            snap = reg.snapshot()
        assert not (tmp_path / "step_00000007.tmp").exists()
        assert snap["counters"]["resilience.ckpt.stale_tmp_removed"] == 1

    def test_async_checkpointer_cleans_tmp_on_startup(self, tmp_path):
        os.makedirs(tmp_path / "step_00000001.tmp")
        ckpt.AsyncCheckpointer(str(tmp_path))
        assert not (tmp_path / "step_00000001.tmp").exists()

    def test_structure_mismatch_names_path(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree())
        with pytest.raises(ckpt.StructureMismatchError, match=r"\['a'\]"):
            ckpt.restore(str(tmp_path), 1, {"x": jnp.zeros((2,))})

    def test_restore_latest_valid_skips_structure_mismatch(self, tmp_path):
        """Regression: a stale checkpoint from an older model config in
        the same dir must be walked past, not crash the restore."""
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        ckpt.save(str(tmp_path), 2, {"x": jnp.zeros((2,))})  # old config
        with obs.scoped() as reg:
            step, out = ckpt.restore_latest_valid(str(tmp_path),
                                                  jax.eval_shape(_tree))
            snap = reg.snapshot()
        assert step == 1
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert snap["counters"]["resilience.ckpt.structure_skipped"] == 1

    def test_async_write_failure_reraised_from_wait(self, tmp_path):
        """Regression: a failed write used to die silently on the thread."""
        with obs.scoped() as reg:
            c = ckpt.AsyncCheckpointer(str(tmp_path))
            with resilience.chaos(Fault("ckpt.write", mode="raise")):
                c.save(1, _tree())
                with pytest.raises(FaultInjected):
                    c.wait()
            snap = reg.snapshot()
        assert snap["counters"]["resilience.ckpt.write_failures"] == 1
        assert ckpt.latest_step(str(tmp_path)) is None
        c.save(2, _tree())                    # checkpointer still usable
        c.wait()
        assert ckpt.latest_step(str(tmp_path)) == 2

    def test_async_write_failure_surfaces_before_next_save(self, tmp_path):
        c = ckpt.AsyncCheckpointer(str(tmp_path))
        with resilience.chaos(Fault("ckpt.write", mode="raise")):
            c.save(1, _tree())
            time.sleep(0.05)                  # let the write thread fail
            with pytest.raises(FaultInjected):
                c.save(2, _tree())


# ==================================================== trainer hardening ==
class _ToyModel:
    """Deterministic 1-param 'model': good steps add mean(tokens)-coupled
    increments so the loss trajectory is a pure function of the data
    stream (what kill-and-resume must replay exactly)."""

    def init(self, key):
        return {"w": jnp.zeros(())}


def _toy_step(params, opt_state, batch):
    tok_mean = jnp.mean(batch["tokens"].astype(jnp.float32))
    w = params["w"] + 1.0
    loss = jnp.abs(tok_mean - w) / (tok_mean + 1.0)
    opt_state = dict(opt_state)
    opt_state["count"] = opt_state["count"] + 1
    return {"w": w}, opt_state, {"total_loss": loss}


def _toy_trainer(tmp_path, total_steps=6, **cfg_kw):
    data = SyntheticLM(32, 8, 2, seed=0)
    tcfg = TrainerConfig(total_steps=total_steps,
                         ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=1,
                         log_every=100, watchdog_s=600, **cfg_kw)
    return Trainer(_ToyModel(), adamw.AdamWConfig(), data, _toy_step, tcfg)


class TestTrainerHardening:
    def test_nan_loss_skips_step(self, tmp_path):
        with obs.scoped() as reg:
            tr = _toy_trainer(tmp_path, total_steps=5, max_bad_steps=10)
            with resilience.chaos(Fault("train.loss", mode="corrupt",
                                        after=1, times=2)):
                out = tr.run()
            snap = reg.snapshot()
        assert snap["counters"]["train.skipped_steps"] == 2
        statuses = [h["status"] for h in out["history"]]
        assert statuses.count("skipped") == 2
        # 5 steps, 2 skipped -> only 3 applied updates
        assert float(tr.params["w"]) == 3.0

    def test_rollback_after_consecutive_bad_steps(self, tmp_path):
        with obs.scoped() as reg:
            tr = _toy_trainer(tmp_path, total_steps=5, max_bad_steps=2)
            with resilience.chaos(Fault("train.loss", mode="corrupt",
                                        after=2, times=2)):
                tr.run()
            snap = reg.snapshot()
        assert snap["counters"]["resilience.train.rollbacks"] == 1
        assert snap["counters"]["train.skipped_steps"] == 2
        # rollback restored step-2 state, then steps 3..5 applied cleanly
        assert float(tr.params["w"]) == 5.0

    def test_rollback_bounded_aborts_on_persistent_divergence(self,
                                                              tmp_path):
        """Regression: deterministic replay means a rollback re-runs the
        same bad batches — unbounded rollbacks livelock forever; past
        max_rollbacks the trainer must abort instead."""
        with obs.scoped() as reg:
            tr = _toy_trainer(tmp_path, total_steps=4, max_bad_steps=2,
                              max_rollbacks=1)
            # a valid step-0 checkpoint to roll back to, written
            # synchronously so the test never races the async checkpointer
            ckpt.save(str(tmp_path / "ckpt"), 0,
                      {"params": tr.params, "opt": tr.opt_state})
            with resilience.chaos(Fault("train.loss", mode="corrupt",
                                        times=None)):
                with pytest.raises(TrainingDivergedError,
                                   match="deterministic replay"):
                    tr.run()
            snap = reg.snapshot()
        assert tr.rollbacks == 1
        assert snap["counters"]["resilience.train.rollbacks"] == 1

    def test_donating_step_rejected_with_finite_checks(self, tmp_path):
        """Regression: finite_checks reuses pre-step buffers, which a
        donating train_step frees on device — the inconsistent wiring
        must fail loudly at init, not with 'Array has been deleted' on
        the first skipped step (which CPU CI would never see)."""
        data = SyntheticLM(32, 8, 2, seed=0)
        with pytest.raises(ValueError, match="non-donating"):
            Trainer(_ToyModel(), adamw.AdamWConfig(), data, _toy_step,
                    TrainerConfig(total_steps=1), step_donates=True)
        # with the guard off, donation is a legitimate perf choice
        Trainer(_ToyModel(), adamw.AdamWConfig(), data, _toy_step,
                TrainerConfig(total_steps=1, finite_checks=False),
                step_donates=True)

    def test_watchdog_escalates_to_recovery_cb(self, tmp_path):
        calls = []
        with obs.scoped() as reg:
            tr = _toy_trainer(tmp_path, total_steps=1,
                              watchdog_escalate_after=1,
                              recovery_cb=calls.append)
            tr.cfg.watchdog_s = 0.05
            tr.watchdog.deadline = 0.05
            with resilience.chaos(Fault("train.step", mode="delay",
                                        delay_s=0.3)):
                out = tr.run()
            snap = reg.snapshot()
        assert out["watchdog_fired"] >= 1
        assert calls, "recovery callback never invoked"
        assert snap["counters"]["resilience.train.watchdog_fired"] >= 1
        assert snap["counters"][
            "resilience.train.watchdog_escalations"] >= 1

    def test_ckpt_write_failure_does_not_kill_training(self, tmp_path):
        with obs.scoped() as reg:
            tr = _toy_trainer(tmp_path, total_steps=4)
            with resilience.chaos(Fault("ckpt.write", mode="raise",
                                        times=2)):
                out = tr.run()
            snap = reg.snapshot()
        assert out["steps"] == 4                      # no crash
        assert out["ckpt_errors"] >= 1
        assert snap["counters"]["resilience.train.ckpt_failures"] >= 1
        assert snap["counters"]["resilience.ckpt.write_failures"] == 2
        # later writes landed despite the early failures
        assert ckpt.latest_step(str(tmp_path / "ckpt")) == 4

    def test_data_stall_injection_is_survivable(self, tmp_path):
        with obs.scoped() as reg:
            tr = _toy_trainer(tmp_path, total_steps=3)
            with resilience.chaos(Fault("data.batch", mode="delay",
                                        delay_s=0.05, times=1)):
                out = tr.run()
            snap = reg.snapshot()
        assert out["steps"] == 3
        assert snap["counters"]["resilience.injected.data.batch"] == 1

    def test_kill_and_resume_matches_uninterrupted(self, tmp_path):
        """SIGKILL-style interruption (exception mid-run, no wait()):
        restart restores the latest valid checkpoint, replays
        data.batch(step) deterministically, and the loss trajectory +
        final params match an uninterrupted run."""
        # interrupted run: hard-raise inside step 5 of 8 (no cleanup path)
        tr1 = _toy_trainer(tmp_path, total_steps=8)
        with resilience.chaos(Fault("train.step", mode="raise", after=4)):
            with pytest.raises(FaultInjected):
                tr1.run()
        # async writes from completed steps may still be in flight; a real
        # SIGKILL would leave at most a torn .tmp, which restore skips.
        # save(N) joins the write of N-1 first, so >= step 3 is durable.
        tr2 = _toy_trainer(tmp_path, total_steps=8)
        assert tr2.start_step in (3, 4)   # latest *valid* checkpoint
        out2 = tr2.run()

        ref = _toy_trainer(tmp_path / "ref", total_steps=8)
        out_ref = ref.run()
        np.testing.assert_allclose(float(tr2.params["w"]),
                                   float(ref.params["w"]))
        resumed = {h["step"]: h["loss"] for h in out2["history"]}
        for h in out_ref["history"]:
            if h["step"] in resumed:
                np.testing.assert_allclose(resumed[h["step"]], h["loss"],
                                           rtol=1e-6)

    def test_trainer_init_skips_corrupt_latest(self, tmp_path):
        tr1 = _toy_trainer(tmp_path, total_steps=3)
        tr1.run()
        _corrupt_npz(str(tmp_path / "ckpt" / "step_00000003"))
        tr2 = _toy_trainer(tmp_path, total_steps=3)
        assert tr2.start_step == 2        # fell back past the corrupt step

    def test_prefetcher_propagates_source_crash(self):
        class Bad:
            def batch(self, step):
                raise OSError("disk gone")
        pf = Prefetcher(Bad(), depth=1)
        with pytest.raises(OSError, match="disk gone"):
            pf.next()
        # regression: the worker thread is gone — every later call must
        # fail fast too, not block forever on the empty queue
        with pytest.raises(OSError, match="disk gone"):
            pf.next()
        pf.close()


# ===================================================== serve hardening ==
@pytest.fixture(scope="module")
def serve_setup():
    cfg = reduced(get_config("starcoder2-3b"), n_layers=1, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_size=2, max_len=32)
    return cfg, model, params, eng


@pytest.fixture(scope="module")
def mca_setup():
    from repro.core.policy import MCAConfig
    cfg = reduced(get_config("starcoder2-3b"), n_layers=1, vocab_size=128,
                  mca=MCAConfig(enabled=True, alpha=0.4, block=16,
                                sites=("v_proj",)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng_on = Engine(model, params, batch_size=2, max_len=32,
                    mca_enabled=True)
    eng_off = Engine(model, params, batch_size=2, max_len=32,
                     mca_enabled=False)
    return cfg, eng_on, eng_off


class TestServeAdmission:
    def test_oversized_prompt_rejected(self, serve_setup):
        cfg, model, params, eng = serve_setup
        b = ContinuousBatcher(eng)
        long_prompt = np.ones(40, np.int32)          # 40 + 4 > max_len 32
        with obs.scoped() as reg:
            status = b.submit(Request(uid=0, prompt=long_prompt, max_new=4))
            snap = reg.snapshot()
        assert status == "rejected"
        assert b.status[0] == "rejected"
        assert snap["counters"]["serve.rejected.prompt_too_long"] == 1
        assert not b.queue                           # never enters a wave

    def test_queue_overflow_rejected(self, serve_setup):
        cfg, model, params, eng = serve_setup
        b = ContinuousBatcher(eng, max_queue=2)
        p = np.ones(4, np.int32)
        with obs.scoped() as reg:
            sts = [b.submit(Request(uid=i, prompt=p, max_new=2))
                   for i in range(3)]
            snap = reg.snapshot()
        assert sts == ["queued", "queued", "rejected"]
        assert snap["counters"]["serve.rejected.queue_full"] == 1

    def test_engine_generate_validates_cache_capacity(self, serve_setup):
        cfg, model, params, eng = serve_setup
        prompts = np.ones((2, 30), np.int32)
        with pytest.raises(ValueError, match="overruns"):
            eng.generate(prompts, max_new=8)

    def test_wave_assembly_is_capacity_aware(self, serve_setup):
        """Regression: two individually-admissible requests whose joint
        max(prompt)+max(max_new) overruns max_len used to be batched into
        one wave, fail generate's capacity check deterministically, and
        take the whole wave down as FAILED.  They must run in separate
        waves instead."""
        cfg, model, params, eng = serve_setup      # max_len=32, batch=2
        b = ContinuousBatcher(eng)
        b.submit(Request(uid=0, prompt=np.ones(20, np.int32), max_new=4))
        b.submit(Request(uid=1, prompt=np.ones(4, np.int32), max_new=20))
        with obs.scoped() as reg:
            done = b.run()
            snap = reg.snapshot()
        assert b.status == {0: "ok", 1: "ok"}
        assert len(done[0]) == 4 and len(done[1]) == 20
        assert snap["counters"]["serve.waves"] == 2
        # deterministic capacity errors must not burn retries
        assert "resilience.serve.wave_retries" not in snap["counters"]

    def test_deadline_timeout(self, serve_setup):
        cfg, model, params, eng = serve_setup
        b = ContinuousBatcher(eng)
        p = np.ones(4, np.int32)
        b.submit(Request(uid=0, prompt=p, max_new=2, deadline_s=0.0))
        b.submit(Request(uid=1, prompt=p, max_new=2))
        time.sleep(0.01)
        with obs.scoped() as reg:
            done = b.run()
            snap = reg.snapshot()
        assert b.status[0] == "timeout" and 0 not in done
        assert b.status[1] == "ok" and 1 in done
        assert snap["counters"]["resilience.serve.timeouts"] == 1

    def test_dummy_slots_excluded_from_metrics(self, serve_setup):
        """Satellite: a half-empty wave must not double-count tokens."""
        cfg, model, params, eng = serve_setup
        b = ContinuousBatcher(eng)
        rng = np.random.default_rng(0)
        with obs.scoped() as reg:
            b.submit(Request(uid=0, max_new=4,
                             prompt=rng.integers(1, 128, 6).astype(np.int32)))
            b.run()                      # 1 real request, 1 dummy slot
            snap = reg.snapshot()
        assert snap["counters"]["serve.generated_tokens"] == 4
        assert snap["gauges"]["serve.slot_utilization"] == 0.5


class TestServeDegradation:
    def test_nan_logits_degrade_to_exact_and_match_mca_off(self, mca_setup):
        """Acceptance: the exact-attention fallback wave is token-identical
        to an MCA-off engine on the same prompts/params."""
        cfg, eng_on, eng_off = mca_setup
        rng = np.random.default_rng(7)
        prompts = [rng.integers(1, cfg.vocab_size, 6).astype(np.int32)
                   for _ in range(2)]
        want = eng_off.generate(np.stack(prompts), max_new=4)

        b = ContinuousBatcher(eng_on)
        for uid, p in enumerate(prompts):
            b.submit(Request(uid=uid, prompt=p, max_new=4))
        with obs.scoped() as reg:
            # poison the first (MCA) attempt's logits; the exact retry
            # passes the finite check untouched
            with resilience.chaos(Fault("serve.prefill", mode="corrupt",
                                        times=1)):
                done = b.run()
            snap = reg.snapshot()
        assert b.status == {0: "degraded", 1: "degraded"}
        for uid in (0, 1):
            assert done[uid] == want[uid].tolist()
        assert snap["counters"]["resilience.serve.wave_retries"] == 1
        assert snap["counters"]["resilience.serve.degraded_waves"] == 1
        assert snap["counters"][
            "resilience.injected.serve.prefill"] == 1

    def test_decode_fault_retries_wave(self, mca_setup):
        cfg, eng_on, eng_off = mca_setup
        p = np.ones(5, np.int32)
        b = ContinuousBatcher(eng_on)
        b.submit(Request(uid=0, prompt=p, max_new=3))
        with obs.scoped() as reg:
            with resilience.chaos(Fault("serve.decode", mode="raise",
                                        times=1)):
                done = b.run()
            snap = reg.snapshot()
        assert 0 in done and b.status[0] == "degraded"
        assert snap["counters"]["resilience.serve.wave_retries"] == 1

    def test_persistent_fault_fails_wave_without_crash(self, serve_setup):
        cfg, model, params, eng = serve_setup
        b = ContinuousBatcher(eng, max_retries=1, backoff_s=0.0)
        p = np.ones(4, np.int32)
        b.submit(Request(uid=0, prompt=p, max_new=2))
        b.submit(Request(uid=1, prompt=p, max_new=2))
        with obs.scoped() as reg:
            with resilience.chaos(Fault("serve.prefill", mode="corrupt",
                                        times=None)):
                done = b.run()                       # exhausts the ladder
            snap = reg.snapshot()
        assert done == {}
        assert b.status == {0: "failed", 1: "failed"}
        assert snap["counters"]["resilience.serve.failed_requests"] == 2

    def test_mca_off_engine_plain_retry_stays_ok(self, serve_setup):
        """A transient fault on an exact engine retries without claiming
        degradation (nothing was approximated away)."""
        cfg, model, params, eng = serve_setup
        b = ContinuousBatcher(eng)
        p = np.ones(4, np.int32)
        b.submit(Request(uid=0, prompt=p, max_new=2))
        with resilience.chaos(Fault("serve.prefill", mode="corrupt",
                                    times=1)):
            done = b.run()
        assert b.status[0] == "ok" and 0 in done


# ============================================== end-to-end observability ==
def test_recovery_counters_visible_in_scoped_snapshot(tmp_path, mca_setup):
    """Acceptance: a chaos run leaves a coherent resilience.* trail in one
    obs.scoped() snapshot spanning serve + train + checkpoint faults."""
    cfg, eng_on, _ = mca_setup
    with obs.scoped() as reg:
        b = ContinuousBatcher(eng_on)
        b.submit(Request(uid=0, prompt=np.ones(5, np.int32), max_new=2))
        with resilience.chaos(Fault("serve.prefill", mode="corrupt",
                                    times=1),
                              Fault("ckpt.write", mode="raise", times=1),
                              Fault("train.loss", mode="corrupt", times=1)):
            b.run()
            tr = _toy_trainer(tmp_path, total_steps=2, max_bad_steps=5)
            tr.run()
        snap = reg.snapshot()
    c = snap["counters"]
    assert c["resilience.injected.serve.prefill"] == 1
    assert c["resilience.serve.degraded_waves"] == 1
    assert c["train.skipped_steps"] == 1
    assert c["resilience.ckpt.write_failures"] == 1
    resil = {k for k in c if k.startswith("resilience.")}
    assert len(resil) >= 4


# ============================================ per-slot insertion chaos ==
class TestSlotBatcherChaos:
    def test_insert_corrupt_degrades_one_request(self, mca_setup):
        """A poisoned insertion retries exact for THAT request only: it
        finishes degraded and token-identical to an MCA-off engine; the
        other request stays ok."""
        from repro.serve import SlotBatcher
        cfg, eng_on, eng_off = mca_setup
        rng = np.random.default_rng(11)
        prompts = [rng.integers(1, cfg.vocab_size, 6).astype(np.int32)
                   for _ in range(2)]
        want0 = eng_off.generate(np.stack([prompts[0]] * 2),
                                 max_new=4)[0].tolist()
        b = SlotBatcher(eng_on, backoff_s=0.0)
        for uid, p in enumerate(prompts):
            b.submit(Request(uid=uid, prompt=p, max_new=4))
        with obs.scoped() as reg:
            with resilience.chaos(Fault("serve.insert", mode="corrupt",
                                        times=1)):
                done = b.run()
            snap = reg.snapshot()
        assert b.status[0] == "degraded" and b.status[1] == "ok"
        assert done[0] == want0, "exact retry must match the MCA-off engine"
        assert len(done[1]) == 4
        c = snap["counters"]
        assert c["resilience.serve.insert_retries"] == 1
        assert c["resilience.serve.degraded_requests"] == 1
        assert c["resilience.injected.serve.insert"] == 1

    def test_insert_persistent_fault_fails_only_requests(self, serve_setup):
        """serve.insert raising on every attempt fails the requests — the
        batcher never crashes and the engine stays usable."""
        from repro.serve import SlotBatcher
        cfg, model, params, eng = serve_setup
        b = SlotBatcher(eng, max_retries=1, backoff_s=0.0)
        p = np.ones(4, np.int32)
        b.submit(Request(uid=0, prompt=p, max_new=2))
        b.submit(Request(uid=1, prompt=p, max_new=2))
        with obs.scoped() as reg:
            with resilience.chaos(Fault("serve.insert", mode="raise",
                                        times=None)):
                done = b.run()
            snap = reg.snapshot()
        assert done == {}
        assert b.status == {0: "failed", 1: "failed"}
        assert snap["counters"]["resilience.serve.failed_requests"] == 2
        # engine still serves after the chaos plan is gone
        b2 = SlotBatcher(eng)
        b2.submit(Request(uid=2, prompt=p, max_new=2))
        assert len(b2.run()[2]) == 2

    def test_decode_fault_retries_burst(self, serve_setup):
        """A transient decode fault retries the burst; active chaos also
        forces K=1 so the fault surfaces at per-step granularity."""
        from repro.serve import SlotBatcher
        cfg, model, params, eng = serve_setup
        b = SlotBatcher(eng, backoff_s=0.0, check_every=8)
        p = np.ones(5, np.int32)
        b.submit(Request(uid=0, prompt=p, max_new=3))
        with obs.scoped() as reg:
            with resilience.chaos(Fault("serve.decode", mode="raise",
                                        times=1)):
                done = b.run()
            snap = reg.snapshot()
        assert b.status[0] == "ok" and len(done[0]) == 3
        assert snap["counters"]["resilience.serve.decode_retries"] == 1
