"""Unit tests for the benchmarks.compare regression gate."""
import copy
import json

import pytest

from benchmarks import compare as C


def _report():
    return {
        "schema_version": 1,
        "profile": "smoke",
        "kernels": [
            {"name": "mca_sampled_matmul", "us_per_call": 400.0,
             "flops_reduction": 4.0},
            {"name": "chunked_attention", "us_per_call": 20_000.0},
        ],
        "tables": {
            "table1": [
                {"task": "syn-cola", "baseline_acc": 0.8, "rows": [
                    {"alpha": 0.0, "acc": 0.80, "ci95": 0.0,
                     "acc_delta": 0.0, "flops_reduction": 1.0,
                     "tier_hist": [0.1, 0.2, 0.3, 0.4]},
                    {"alpha": 0.2, "acc": 0.78, "ci95": 0.01,
                     "acc_delta": -0.02, "flops_reduction": 1.5,
                     "tier_hist": [0.0, 0.1, 0.4, 0.5]},
                ]},
            ],
        },
        "serve_throughput": {
            "n_requests": 12, "n_tokens": 72, "batch": 4, "max_len": 96,
            "rows": [
                {"batcher": "wave", "tokens_per_s": 2000.0,
                 "prefill_tokens": 372.0, "prefill_flops_ratio": 1.0,
                 "parity_ok": True},
                {"batcher": "per_slot", "tokens_per_s": 2500.0,
                 "prefill_tokens": 208.0, "prefill_flops_ratio": 1.79,
                 "parity_ok": True},
            ],
        },
        "fig1": None,
        "obs": {"counters": {}, "gauges": {}, "histograms": {}},
    }


def test_self_compare_is_clean():
    r = _report()
    assert C.compare(r, copy.deepcopy(r)) == []


def test_kernel_timing_blowup_flagged():
    cand = _report()
    cand["kernels"][0]["us_per_call"] = 400.0 * 3.0     # > 2.5x
    probs = C.compare(_report(), cand)
    assert any("mca_sampled_matmul" in p for p in probs)


def test_kernel_timing_within_ratio_ok():
    cand = _report()
    cand["kernels"][0]["us_per_call"] = 400.0 * 2.0     # < 2.5x
    assert C.compare(_report(), cand) == []


def test_missing_kernel_flagged():
    cand = _report()
    cand["kernels"].pop()
    probs = C.compare(_report(), cand)
    assert any("chunked_attention" in p and "missing" in p for p in probs)


def test_accuracy_drift_flagged():
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.70    # |d|=0.08 > 0.05
    probs = C.compare(_report(), cand)
    assert any("acc" in p and "alpha=0.2" in p for p in probs)


def test_flops_reduction_drift_flagged():
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["flops_reduction"] = 2.5
    probs = C.compare(_report(), cand)
    assert any("flops_reduction" in p for p in probs)


def test_tier_hist_drift_flagged():
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["tier_hist"] = [0.5, 0.4, 0.1, 0.0]
    probs = C.compare(_report(), cand)
    assert any("tier_hist" in p for p in probs)


def test_threshold_override_loosens_gate():
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.70
    assert C.compare(_report(), cand, {"accuracy_abs": 0.2}) == []


def test_serve_tokens_per_s_regression_flagged():
    cand = _report()
    cand["serve_throughput"]["rows"][1]["tokens_per_s"] = 2500.0 * 0.85
    probs = C.compare(_report(), cand)               # 15% drop > 10% gate
    assert any("per_slot" in p and "tokens_per_s" in p for p in probs)


def test_serve_tokens_per_s_within_gate_ok():
    cand = _report()
    cand["serve_throughput"]["rows"][1]["tokens_per_s"] = 2500.0 * 0.95
    assert C.compare(_report(), cand) == []


def test_serve_parity_failure_flagged():
    cand = _report()
    cand["serve_throughput"]["rows"][1]["parity_ok"] = False
    probs = C.compare(_report(), cand)
    assert any("parity" in p for p in probs)


def test_serve_prefill_ratio_drop_flagged():
    cand = _report()
    cand["serve_throughput"]["rows"][1]["prefill_flops_ratio"] = 1.2
    probs = C.compare(_report(), cand)
    assert any("prefill_flops_ratio" in p for p in probs)


def test_serve_missing_section_flagged():
    cand = _report()
    del cand["serve_throughput"]
    probs = C.compare(_report(), cand)
    assert any("serve_throughput" in p and "missing" in p for p in probs)


def test_profile_mismatch_raises():
    cand = _report()
    cand["profile"] = "full"
    with pytest.raises(ValueError, match="profile"):
        C.compare(_report(), cand)


def test_schema_mismatch_raises():
    cand = _report()
    cand["schema_version"] = 2
    with pytest.raises(ValueError, match="schema_version"):
        C.compare(_report(), cand)


# ------------------------------------------------------------------ CLI
def _write(tmp_path, name, rep):
    p = tmp_path / name
    p.write_text(json.dumps(rep))
    return str(p)


def test_cli_clean_exits_zero(tmp_path, capsys):
    b = _write(tmp_path, "b.json", _report())
    c = _write(tmp_path, "c.json", _report())
    assert C.main([b, c]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_regression_exits_one(tmp_path, capsys):
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.5
    b = _write(tmp_path, "b.json", _report())
    c = _write(tmp_path, "c.json", cand)
    assert C.main([b, c]) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_cli_report_only_exits_zero(tmp_path, capsys):
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.5
    b = _write(tmp_path, "b.json", _report())
    c = _write(tmp_path, "c.json", cand)
    assert C.main([b, c, "--report-only"]) == 0
    assert "REGRESSION" in capsys.readouterr().out


def test_cli_threshold_flag(tmp_path):
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.70
    b = _write(tmp_path, "b.json", _report())
    c = _write(tmp_path, "c.json", cand)
    assert C.main([b, c, "--threshold", "accuracy_abs=0.2"]) == 0
    assert C.main([b, c, "--threshold", "bogus=1"]) == 2


def test_cli_bad_file_exits_two(tmp_path):
    b = _write(tmp_path, "b.json", _report())
    assert C.main([b, str(tmp_path / "missing.json")]) == 2


def test_checked_in_baseline_self_compares_clean():
    """The repo's BENCH_9.json must stay loadable and self-consistent."""
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_9.json")
    with open(path) as f:
        rep = json.load(f)
    assert rep["schema_version"] == 1
    assert C.compare(rep, copy.deepcopy(rep)) == []
    assert {"table1", "table2", "table3"} <= set(rep["tables"])
    assert rep["kernels"], "kernel timings missing"
    rows = {r["batcher"]: r for r in rep["serve_throughput"]["rows"]}
    assert rows["per_slot"]["parity_ok"] is True
    # acceptance bar: per-slot insertion saves >= 1.5x prefill FLOPs on
    # the ragged Zipf workload
    assert rows["per_slot"]["prefill_flops_ratio"] >= 1.5


def test_extra_obs_keys_never_gate():
    """The obs section (p99 percentiles, device_launches counters, span
    histograms) is informational — compare must not read it."""
    cand = _report()
    cand["obs"] = {
        "counters": {"kernels.kv_slot_update.device_launches": 37.0},
        "gauges": {"serve.slot_utilization": 0.9},
        "histograms": {"serve.prefill_seconds": {
            "count": 4, "sum": 0.4, "mean": 0.1, "min": 0.05, "max": 0.2,
            "p50": 0.1, "p95": 0.2, "p99": 0.2}},
    }
    assert C.compare(_report(), cand) == []


def test_trace_file_rejected_not_compared(tmp_path, capsys):
    """--trace-out Chrome traces live next to bench JSONs in CI
    artifacts; feeding one to the gate must fail loudly (exit 2), never
    be silently diffed."""
    b = _write(tmp_path, "b.json", _report())
    t = _write(tmp_path, "trace.json",
               {"traceEvents": [], "displayTimeUnit": "ms"})
    assert C.main([b, t]) == 2
    assert "Chrome trace" in capsys.readouterr().err
    assert C.main([t, b]) == 2


def test_latency_table_renders_percentiles():
    from benchmarks import report as R
    h = {"count": 3, "sum": 0.3, "mean": 0.1, "min": 0.05, "max": 0.2,
         "p50": 0.1, "p95": 0.18, "p99": 0.2}
    snap = {"histograms": {"serve.prefill_seconds": h,
                           "train.step_seconds": dict(h, count=7),
                           "serve.queue_depth": h}}    # not a duration
    md = R.latency_table(snap)
    assert "| serve.prefill_seconds | 3 | 100.00 | 180.00 | 200.00 |" in md
    assert "train.step_seconds" in md
    assert "queue_depth" not in md
    assert R.latency_table({"histograms": {}}).count("\n") == 2


def test_report_bench_mode_prints_table(tmp_path, capsys):
    from benchmarks import report as R
    rep = _report()
    rep["obs"]["histograms"] = {"serve.wave_seconds": {
        "count": 2, "sum": 0.2, "mean": 0.1, "min": 0.08, "max": 0.12,
        "p50": 0.1, "p95": 0.12, "p99": 0.12}}
    p = _write(tmp_path, "bench.json", rep)
    import sys
    argv = sys.argv
    sys.argv = ["report", "--bench", p]
    try:
        R.main()
    finally:
        sys.argv = argv
    out = capsys.readouterr().out
    assert "serve.wave_seconds" in out and "p99 ms" in out
