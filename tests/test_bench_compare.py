"""Unit tests for the benchmarks.compare regression gate."""
import copy
import json

import pytest

from benchmarks import compare as C


def _report():
    return {
        "schema_version": 1,
        "profile": "smoke",
        "kernels": [
            {"name": "mca_sampled_matmul", "us_per_call": 400.0,
             "flops_reduction": 4.0},
            {"name": "chunked_attention", "us_per_call": 20_000.0},
        ],
        "tables": {
            "table1": [
                {"task": "syn-cola", "baseline_acc": 0.8, "rows": [
                    {"alpha": 0.0, "acc": 0.80, "ci95": 0.0,
                     "acc_delta": 0.0, "flops_reduction": 1.0,
                     "tier_hist": [0.1, 0.2, 0.3, 0.4]},
                    {"alpha": 0.2, "acc": 0.78, "ci95": 0.01,
                     "acc_delta": -0.02, "flops_reduction": 1.5,
                     "tier_hist": [0.0, 0.1, 0.4, 0.5]},
                ]},
            ],
        },
        "serve_throughput": {
            "n_requests": 12, "n_tokens": 72, "batch": 4, "max_len": 96,
            "rows": [
                {"batcher": "wave", "tokens_per_s": 2000.0,
                 "prefill_tokens": 372.0, "prefill_flops_ratio": 1.0,
                 "parity_ok": True},
                {"batcher": "per_slot", "tokens_per_s": 2500.0,
                 "prefill_tokens": 208.0, "prefill_flops_ratio": 1.79,
                 "parity_ok": True},
            ],
        },
        "fig1": None,
        "obs": {"counters": {}, "gauges": {}, "histograms": {}},
    }


def test_self_compare_is_clean():
    r = _report()
    assert C.compare(r, copy.deepcopy(r)) == []


def test_kernel_timing_blowup_flagged():
    cand = _report()
    cand["kernels"][0]["us_per_call"] = 400.0 * 3.0     # > 2.5x
    probs = C.compare(_report(), cand)
    assert any("mca_sampled_matmul" in p for p in probs)


def test_kernel_timing_within_ratio_ok():
    cand = _report()
    cand["kernels"][0]["us_per_call"] = 400.0 * 2.0     # < 2.5x
    assert C.compare(_report(), cand) == []


def test_missing_kernel_flagged():
    cand = _report()
    cand["kernels"].pop()
    probs = C.compare(_report(), cand)
    assert any("chunked_attention" in p and "missing" in p for p in probs)


def test_accuracy_drift_flagged():
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.70    # |d|=0.08 > 0.05
    probs = C.compare(_report(), cand)
    assert any("acc" in p and "alpha=0.2" in p for p in probs)


def test_flops_reduction_drift_flagged():
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["flops_reduction"] = 2.5
    probs = C.compare(_report(), cand)
    assert any("flops_reduction" in p for p in probs)


def test_tier_hist_drift_flagged():
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["tier_hist"] = [0.5, 0.4, 0.1, 0.0]
    probs = C.compare(_report(), cand)
    assert any("tier_hist" in p for p in probs)


def test_threshold_override_loosens_gate():
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.70
    assert C.compare(_report(), cand, {"accuracy_abs": 0.2}) == []


def test_serve_tokens_per_s_regression_flagged():
    cand = _report()
    cand["serve_throughput"]["rows"][1]["tokens_per_s"] = 2500.0 * 0.85
    probs = C.compare(_report(), cand)               # 15% drop > 10% gate
    assert any("per_slot" in p and "tokens_per_s" in p for p in probs)


def test_serve_tokens_per_s_within_gate_ok():
    cand = _report()
    cand["serve_throughput"]["rows"][1]["tokens_per_s"] = 2500.0 * 0.95
    assert C.compare(_report(), cand) == []


def test_serve_parity_failure_flagged():
    cand = _report()
    cand["serve_throughput"]["rows"][1]["parity_ok"] = False
    probs = C.compare(_report(), cand)
    assert any("parity" in p for p in probs)


def test_serve_prefill_ratio_drop_flagged():
    cand = _report()
    cand["serve_throughput"]["rows"][1]["prefill_flops_ratio"] = 1.2
    probs = C.compare(_report(), cand)
    assert any("prefill_flops_ratio" in p for p in probs)


def test_serve_missing_section_flagged():
    cand = _report()
    del cand["serve_throughput"]
    probs = C.compare(_report(), cand)
    assert any("serve_throughput" in p and "missing" in p for p in probs)


def test_profile_mismatch_raises():
    cand = _report()
    cand["profile"] = "full"
    with pytest.raises(ValueError, match="profile"):
        C.compare(_report(), cand)


def test_schema_mismatch_raises():
    cand = _report()
    cand["schema_version"] = 2
    with pytest.raises(ValueError, match="schema_version"):
        C.compare(_report(), cand)


# ------------------------------------------------------------------ CLI
def _write(tmp_path, name, rep):
    p = tmp_path / name
    p.write_text(json.dumps(rep))
    return str(p)


def test_cli_clean_exits_zero(tmp_path, capsys):
    b = _write(tmp_path, "b.json", _report())
    c = _write(tmp_path, "c.json", _report())
    assert C.main([b, c]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_regression_exits_one(tmp_path, capsys):
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.5
    b = _write(tmp_path, "b.json", _report())
    c = _write(tmp_path, "c.json", cand)
    assert C.main([b, c]) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_cli_report_only_exits_zero(tmp_path, capsys):
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.5
    b = _write(tmp_path, "b.json", _report())
    c = _write(tmp_path, "c.json", cand)
    assert C.main([b, c, "--report-only"]) == 0
    assert "REGRESSION" in capsys.readouterr().out


def test_cli_threshold_flag(tmp_path):
    cand = _report()
    cand["tables"]["table1"][0]["rows"][1]["acc"] = 0.70
    b = _write(tmp_path, "b.json", _report())
    c = _write(tmp_path, "c.json", cand)
    assert C.main([b, c, "--threshold", "accuracy_abs=0.2"]) == 0
    assert C.main([b, c, "--threshold", "bogus=1"]) == 2


def test_cli_bad_file_exits_two(tmp_path):
    b = _write(tmp_path, "b.json", _report())
    assert C.main([b, str(tmp_path / "missing.json")]) == 2


def test_checked_in_baseline_self_compares_clean():
    """The repo's BENCH_9.json must stay loadable and self-consistent."""
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_9.json")
    with open(path) as f:
        rep = json.load(f)
    assert rep["schema_version"] == 1
    assert C.compare(rep, copy.deepcopy(rep)) == []
    assert {"table1", "table2", "table3"} <= set(rep["tables"])
    assert rep["kernels"], "kernel timings missing"
    rows = {r["batcher"]: r for r in rep["serve_throughput"]["rows"]}
    assert rows["per_slot"]["parity_ok"] is True
    # acceptance bar: per-slot insertion saves >= 1.5x prefill FLOPs on
    # the ragged Zipf workload
    assert rows["per_slot"]["prefill_flops_ratio"] >= 1.5
