"""Layer-level equivalence tests: chunked vs sequential recurrences,
chunked attention vs naive, MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention as attn
from repro.models import ssm, rglru, ffn as ffn_mod
from repro.models.config import ModelConfig

jax.config.update("jax_platform_name", "cpu")


class TestSSD:
    @pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (48, 12)])
    def test_chunked_matches_sequential(self, s, chunk):
        b, h, p, g, n = 2, 4, 8, 2, 16
        key = jax.random.PRNGKey(s)
        kx, kd, kb, kc = jax.random.split(key, 4)
        xs = jax.random.normal(kx, (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(kd, (b, s, h)))
        a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(9), (h,)) * 0.3)
        bm = jax.random.normal(kb, (b, s, g, n)) * 0.3
        cm = jax.random.normal(kc, (b, s, g, n)) * 0.3
        y1, st1 = ssm.ssd_chunked(xs, dt, a, bm, cm, chunk)
        y2, st2 = ssm.ssd_sequential(xs, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                                   rtol=2e-4, atol=2e-4)

    def test_decay_bounds_state(self):
        """Strongly negative A decays the state to ~0 (stability)."""
        b, s, h, p, g, n = 1, 64, 2, 4, 1, 8
        xs = jnp.ones((b, s, h, p))
        dt = jnp.ones((b, s, h)) * 5.0
        a = jnp.full((h,), -10.0)
        bm = jnp.ones((b, s, g, n))
        cm = jnp.ones((b, s, g, n))
        y, state = ssm.ssd_chunked(xs, dt, a, bm, cm, 16)
        assert bool(jnp.all(jnp.isfinite(y)))
        # with decay ~exp(-50) per step, y_t ~= C.B dt x_t only
        expected = n * 5.0
        np.testing.assert_allclose(np.asarray(y[0, -1, 0, 0]), expected,
                                   rtol=1e-3)


class TestRGLRU:
    def test_scan_matches_stepwise(self):
        cfg = ModelConfig(d_model=32, rnn_width=64, conv_width=4,
                          dtype="float32")
        p = rglru.init_recurrent_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
        y_scan = rglru.rg_lru(p, x)
        h = jnp.zeros((2, 64), jnp.float32)
        outs = []
        for t in range(16):
            y_t, h = rglru.rg_lru_step(p, x[:, t], h)
            outs.append(y_t)
        y_seq = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4)

    def test_gate_keeps_state_bounded(self):
        cfg = ModelConfig(d_model=32, rnn_width=64, dtype="float32")
        p = rglru.init_recurrent_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, 64)) * 10
        y = rglru.rg_lru(p, x)
        assert bool(jnp.all(jnp.isfinite(y)))
        # sqrt(1-a^2) input normalization keeps magnitude ~ input scale
        assert float(jnp.abs(y).max()) < 1e3


class TestChunkedAttention:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000),
           causal=st.booleans(),
           window=st.sampled_from([0, 8]))
    def test_matches_naive(self, seed, causal, window):
        b, sq, hkv, g, dh = 1, 32, 2, 2, 16
        key = jax.random.PRNGKey(seed)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, sq, hkv, g, dh))
        k = jax.random.normal(kk, (b, sq, hkv, dh))
        v = jax.random.normal(kv, (b, sq, hkv, dh))
        scale = dh ** -0.5
        out, m, lse = attn.onepass_attention(q, k, v, scale=scale,
                                             causal=causal, window=window,
                                             chunk=8)
        # naive reference
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * scale
        qpos = jnp.arange(sq)
        mask = attn._mask(qpos, qpos, causal, window)
        s = jnp.where(mask[None, None, None], s, attn.NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        ref = jnp.einsum("bhgqk,bkhd->bqhgd", a, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        # two-pass path agrees with one-pass
        m2, lse2 = attn.chunked_lse(q, k, scale=scale, causal=causal,
                                    window=window, chunk=8)
        out2 = attn.chunked_av(q, k, v, lse2, scale=scale, causal=causal,
                               window=window, chunk=8)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_colmax_matches_naive(self):
        b, sq, hkv, g, dh = 2, 32, 2, 2, 16
        key = jax.random.PRNGKey(3)
        kq, kk = jax.random.split(key)
        q = jax.random.normal(kq, (b, sq, hkv, g, dh))
        k = jax.random.normal(kk, (b, sq, hkv, dh))
        scale = dh ** -0.5
        _, lse = attn.chunked_lse(q, k, scale=scale, causal=True, window=0,
                                  chunk=8)
        cm = attn.chunked_colmax(q, k, lse, scale=scale, causal=True,
                                 window=0, chunk=8)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * scale
        mask = jnp.tril(jnp.ones((sq, sq), bool))
        s = jnp.where(mask[None, None, None], s, attn.NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        ref = jnp.max(a, axis=(1, 2, 3))
        np.testing.assert_allclose(np.asarray(cm), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestMoE:
    def _cfg(self, **kw):
        base = dict(d_model=32, d_ff=64, n_experts=4, top_k=2,
                    capacity_factor=2.0, ffn_type="swiglu", dtype="float32")
        base.update(kw)
        return ModelConfig(**base)

    def test_all_tokens_processed_when_capacity_ample(self):
        cfg = self._cfg()
        p = ffn_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        y, aux, _ = ffn_mod.moe_ffn(p, cfg, x)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))
        assert float(aux) > 0
        # every token got nonzero output (no silent drops at cf=2=E/k)
        norms = jnp.linalg.norm(y.reshape(-1, 32), axis=-1)
        assert float(norms.min()) > 0

    def test_capacity_drops_under_pressure(self):
        cfg = self._cfg(capacity_factor=0.1)
        p = ffn_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
        y, _, _ = ffn_mod.moe_ffn(p, cfg, x)
        norms = jnp.linalg.norm(y.reshape(-1, 32), axis=-1)
        assert float(norms.min()) == 0.0  # some tokens dropped

    def test_router_importance_mca(self):
        from repro.core.policy import MCAConfig
        cfg = self._cfg(mca=MCAConfig(enabled=True, alpha=0.5, block=8,
                                      sites=("expert_ffn",)))
        p = ffn_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        y, _, stats = ffn_mod.moe_ffn(p, cfg, x,
                                      mca_key=jax.random.PRNGKey(2))
        assert bool(jnp.all(jnp.isfinite(y)))
        assert float(stats["mca_flops"]) > 0
        assert float(stats["mca_flops"]) <= float(stats["exact_flops"])


class TestBandedLocalAttention:
    @pytest.mark.parametrize("s,window,cq", [(64, 16, 8), (96, 24, 8),
                                             (128, 32, 32)])
    def test_matches_chunked(self, s, window, cq):
        b, hkv, g, dh = 1, 2, 2, 16
        key = jax.random.PRNGKey(s + window)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, s, hkv, g, dh))
        k = jax.random.normal(kk, (b, s, hkv, dh))
        v = jax.random.normal(kv, (b, s, hkv, dh))
        scale = dh ** -0.5
        ref, m_ref, lse_ref = attn.onepass_attention(
            q, k, v, scale=scale, causal=True, window=window, chunk=cq)
        out, m, lse = attn.banded_onepass(q, k, v, scale=scale,
                                          window=window, chunk_q=cq)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_banded_colmax_matches_chunked(self):
        b, s, hkv, g, dh, window, cq = 1, 64, 2, 1, 16, 16, 8
        key = jax.random.PRNGKey(5)
        kq, kk = jax.random.split(key)
        q = jax.random.normal(kq, (b, s, hkv, g, dh))
        k = jax.random.normal(kk, (b, s, hkv, dh))
        scale = dh ** -0.5
        _, lse_ref = attn.chunked_lse(q, k, scale=scale, causal=True,
                                      window=window, chunk=cq)
        cm_ref = attn.chunked_colmax(q, k, lse_ref, scale=scale, causal=True,
                                     window=window, chunk=cq)
        _, lse, cm = attn.banded_lse_colmax(q, k, scale=scale, window=window,
                                            chunk_q=cq)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(cm), np.asarray(cm_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_gqa_banded_flag_equivalence(self):
        """gqa_attention(banded_local=True) == default chunked path."""
        from repro.configs import get_config
        from repro.models.config import reduced
        cfg = reduced(get_config("recurrentgemma-9b"))
        cfg_b = cfg.replace(banded_local=True)
        key = jax.random.PRNGKey(0)
        p = attn.init_gqa(key, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                              dtype=cfg.jnp_dtype)
        pos = jnp.arange(64)[None]
        y1, _, _, _ = attn.gqa_attention(p, cfg, x, pos=pos,
                                         window=cfg.window)
        y2, _, _, _ = attn.gqa_attention(p, cfg_b, x, pos=pos,
                                         window=cfg.window)
        np.testing.assert_allclose(np.asarray(y1, np.float32),
                                   np.asarray(y2, np.float32),
                                   rtol=2e-3, atol=2e-3)
