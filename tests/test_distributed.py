"""Multi-device SPMD tests — run in a subprocess with 8 forced host devices
(the main pytest process must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.models import build_model, reduced
    from repro.dist import context as dctx, sharding as shd
    from repro.optim import adamw
    from repro.train.step import (abstract_state, jit_train_step,
                                  make_train_step, train_step_shardings)
    from repro.data import SyntheticLM

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    cfg = reduced(get_config("qwen3-32b"), n_layers=2, d_model=64,
                  n_heads=8, n_kv_heads=4, d_head=16, d_ff=128,
                  vocab_size=256)
    model = build_model(cfg)
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
    batch = jax.tree.map(jnp.asarray, data.batch(0))

    with dctx.use_mesh(mesh):
        step = jit_train_step(mesh, model, adamw.AdamWConfig(lr=1e-3),
                              jax.eval_shape(lambda: batch), donate=False)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw.init_state(params)
        in_sh, _ = train_step_shardings(mesh, model, batch)
        params = jax.device_put(params, in_sh[0])
        opt = jax.device_put(opt, in_sh[1])
        batch = jax.device_put(batch, in_sh[2])
        p2, o2, m = step(params, opt, batch)
        loss0 = float(m["total_loss"])
        p3, o3, m = step(p2, o2, batch)
        loss1 = float(m["total_loss"])
    assert np.isfinite(loss0) and np.isfinite(loss1)
    assert loss1 < loss0, (loss0, loss1)

    # verify TP sharding actually applied: ffn w_up sharded over model
    w_up_sh = p2["layers"]["ffn"]["w_up"].sharding
    assert "model" in str(w_up_sh.spec), w_up_sh.spec
    # ZeRO-1: adam moments sharded over data too
    m_sh = o2["m"]["layers"]["ffn"]["w_up"].sharding
    assert "data" in str(m_sh.spec), m_sh.spec
    print("OK losses", loss0, loss1)
""")

_MCA_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.core.policy import MCAConfig
    from repro.models import build_model, reduced
    from repro.dist import context as dctx, sharding as shd
    from repro.data import SyntheticLM

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = reduced(get_config("qwen3-32b"), n_layers=2, d_model=64,
                  n_heads=8, n_kv_heads=4, d_head=16, d_ff=128,
                  vocab_size=256,
                  mca=MCAConfig(enabled=True, alpha=0.4, block=16,
                                sites=("v_proj",)))
    model = build_model(cfg)
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    with dctx.use_mesh(mesh):
        a_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_sh = shd.param_shardings(mesh, a_params, cfg)
        b_sh = shd.batch_shardings(mesh, batch)
        params = jax.device_put(model.init(jax.random.PRNGKey(0)), p_sh)
        batch = jax.device_put(batch, b_sh)
        loss, metrics = jax.jit(
            lambda p, b: model.loss(p, b, jax.random.PRNGKey(1)))(
                params, batch)
        assert np.isfinite(float(loss))
        assert float(metrics["mca_flops"]) > 0
    print("OK mca sharded loss", float(loss))
""")


_SHARD_SAMPLING_SCRIPT = textwrap.dedent("""
    # Regression: the PRNG key enters _tiered_maybe_sharded's shard_map
    # replicated, so every shard used to draw IDENTICAL block samples —
    # estimator errors were perfectly correlated along the token axis and
    # variance did not shrink with mesh size.  With the axis_index fold-in,
    # duplicated rows on different shards must draw different samples and
    # averaging the two shard estimates must reduce the error.
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.policy import MCAConfig, mca_project
    from repro.dist import context as dctx

    mesh = jax.make_mesh((2,), ("data",))
    n, d, f = 16, 256, 64
    half = n // 2
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x_half = jax.random.normal(kx, (half, d))
    x = jnp.concatenate([x_half, x_half])      # shard 1 duplicates shard 0
    w = jax.random.normal(kw, (d, f))
    imp = jnp.full((n,), 0.5)
    exact = np.asarray(x_half @ w)
    cfg = MCAConfig(enabled=True, alpha=0.3, block=16, mode="tiered",
                    sites=("v_proj",))

    diffs, mse_half, mse_comb = [], [], []
    with dctx.use_mesh(mesh):
        for t in range(6):
            y, stats = mca_project(jax.random.PRNGKey(100 + t), x, w,
                                   imp, 64, cfg, "v_proj")
            y = np.asarray(y)
            assert float(stats["mca_flops"]) < float(stats["exact_flops"]), \\
                "schedule did not sample; test vacuous"
            diffs.append(float(np.abs(y[:half] - y[half:]).max()))
            mse_half.append(float(((y[:half] - exact) ** 2).mean()))
            comb = (y[:half] + y[half:]) / 2.0
            mse_comb.append(float(((comb - exact) ** 2).mean()))

    # identical rows on different shards -> different draws
    assert min(diffs) > 1e-6, f"shards drew identical samples: {diffs}"
    # independent draws: averaging the shard estimates cuts the MSE
    mh, mc = np.mean(mse_half), np.mean(mse_comb)
    assert mc < 0.75 * mh, (mh, mc)
    print("OK shard sampling", mh, mc)
""")


_PSUM_SNAPSHOT_SCRIPT = textwrap.dedent("""
    # SPMD-aggregated obs snapshots: additive leaves psum across the world
    # without double counting (each local replica carries 1/n_local), min/
    # max combine with pmin/pmax, empty-histogram nan does not poison them,
    # and repeated aggregated snapshots see identical totals.
    import math
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro import obs
    from repro.obs import devtel

    assert jax.device_count() == 8
    reg = obs.Registry()
    with obs.scoped(reg):
        reg.counter("a").inc(3)
        reg.counter("b").inc(0.5)
        h = reg.histogram("lat_seconds")
        for v in (1.0, 2.0, 5.0):
            h.observe(v)
        reg.histogram("empty")
        agg = obs.snapshot(aggregate="psum")

    assert agg["counters"]["a"] == 3.0, agg["counters"]
    assert agg["counters"]["b"] == 0.5, agg["counters"]
    hh = agg["histograms"]["lat_seconds"]
    assert hh["count"] == 3.0 and hh["sum"] == 8.0, hh
    assert abs(hh["mean"] - 8.0 / 3.0) < 1e-6, hh
    assert hh["min"] == 1.0 and hh["max"] == 5.0, hh
    he = agg["histograms"]["empty"]
    assert he["count"] == 0.0, he
    assert math.isnan(he["min"]) and math.isnan(he["max"]), he
    agg2 = obs.snapshot(aggregate="psum", registry=reg)
    assert agg2["counters"] == agg["counters"]
    assert agg2["histograms"] == agg["histograms"]

    # device telemetry emitted under pmap: one callback per device, all
    # eight land in the same process-global store and the snapshot merge
    devtel.enable(True)

    @jax.pmap
    def f(x):
        devtel.emit("spmd.launches", 1.0)
        return x * 2

    jax.block_until_ready(f(jnp.arange(8.0)))
    devtel.sync()
    snap = reg.snapshot()
    assert snap["counters"]["spmd.launches"] == 8.0, snap["counters"]
    print("OK psum snapshot")
""")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.slow
def test_sharded_train_step_8dev():
    out = _run(_SCRIPT)
    assert "OK losses" in out


@pytest.mark.slow
def test_mca_under_spmd_8dev():
    out = _run(_MCA_SCRIPT)
    assert "OK mca sharded" in out


@pytest.mark.slow
def test_sharded_sampling_independent_across_shards():
    out = _run(_SHARD_SAMPLING_SCRIPT)
    assert "OK shard sampling" in out


@pytest.mark.slow
def test_psum_snapshot_8dev():
    out = _run(_PSUM_SNAPSHOT_SCRIPT)
    assert "OK psum snapshot" in out
