"""Unit + property tests for the Monte-Carlo AMM estimator (core/amm.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import amm

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


class TestBlockProbs:
    def test_sums_to_one(self):
        w = _rand(jax.random.PRNGKey(0), (256, 64))
        p = amm.block_probs(w, block=32)
        assert p.shape == (8,)
        np.testing.assert_allclose(float(jnp.sum(p)), 1.0, rtol=1e-6)

    def test_proportional_to_block_norms(self):
        w = np.zeros((128, 16), np.float32)
        w[:32] = 2.0   # block 0 has 4x the sq norm density of block 1
        w[32:64] = 1.0
        p = np.asarray(amm.block_probs(jnp.asarray(w), block=32))
        assert p[0] > p[1] > p[2]
        np.testing.assert_allclose(p[0] / p[1], 4.0, rtol=1e-5)

    def test_indivisible_raises(self):
        with pytest.raises(ValueError):
            amm.block_probs(jnp.ones((100, 4)), block=32)


class TestSampledMatmul:
    def test_full_sampling_unbiased_mean(self):
        """Monte-Carlo mean over many trials converges to the exact product."""
        key = jax.random.PRNGKey(1)
        kx, kw, ks = jax.random.split(key, 3)
        x = _rand(kx, (16, 128))
        w = _rand(kw, (128, 32))
        exact = x @ w
        probs = amm.block_probs(w, block=16)

        def one(k):
            idx, inv = amm.draw_block_samples(k, probs, 4)
            return amm.sampled_matmul(x, w, idx, inv, block=16)

        trials = jax.vmap(one)(jax.random.split(ks, 2048))
        est = jnp.mean(trials, axis=0)
        rel = float(jnp.linalg.norm(est - exact) / jnp.linalg.norm(exact))
        assert rel < 0.05, f"estimator biased: rel err {rel}"

    def test_exact_when_sampling_every_block_uniform(self):
        """r == K with each block drawn once under uniform p == exact sum."""
        x = _rand(jax.random.PRNGKey(2), (8, 64))
        w = _rand(jax.random.PRNGKey(3), (64, 24))
        k = 4
        idx = jnp.arange(k, dtype=jnp.int32)
        probs = jnp.full((k,), 1.0 / k)
        inv = 1.0 / (k * probs[idx])
        out = amm.sampled_matmul(x, w, idx, inv, block=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                                   rtol=2e-4, atol=2e-4)

    def test_batched_leading_dims(self):
        x = _rand(jax.random.PRNGKey(4), (2, 3, 8, 64))
        w = _rand(jax.random.PRNGKey(5), (64, 16))
        probs = amm.block_probs(w, block=16)
        idx, inv = amm.draw_block_samples(jax.random.PRNGKey(6), probs, 4)
        out = amm.sampled_matmul(x, w, idx, inv, block=16)
        assert out.shape == (2, 3, 8, 16)
        # consistency with 2d path
        out2 = amm.sampled_matmul(x.reshape(-1, 64), w, idx, inv, block=16)
        np.testing.assert_allclose(np.asarray(out).reshape(-1, 16),
                                   np.asarray(out2), rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(n=st.sampled_from([1, 4, 17]),
           kblocks=st.sampled_from([2, 4, 8]),
           f=st.sampled_from([8, 32]),
           r=st.integers(min_value=1, max_value=8),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_variance_bounded_by_lemma1(self, n, kblocks, f, r, seed):
        """Property: E||err|| <= ||X[j]|| ||W||_F / sqrt(r) (paper Lemma 1).

        Estimated over 256 trials; allow 25% slack for MC noise on the
        *expectation* estimate (the bound itself is loose for W-only p).
        """
        block = 16
        d = kblocks * block
        key = jax.random.PRNGKey(seed)
        kx, kw, ks = jax.random.split(key, 3)
        x = _rand(kx, (n, d))
        w = _rand(kw, (d, f))
        probs = amm.block_probs(w, block=block)
        exact = x @ w

        def one(k):
            idx, inv = amm.draw_block_samples(k, probs, r)
            return amm.sampled_matmul(x, w, idx, inv, block=block)

        trials = jax.vmap(one)(jax.random.split(ks, 256))
        err = jnp.linalg.norm(trials - exact[None], axis=-1)  # [T, n]
        mean_err = jnp.mean(err, axis=0)                      # [n]
        bound = (jnp.linalg.norm(x, axis=-1)
                 * jnp.linalg.norm(w) / np.sqrt(r))
        assert bool(jnp.all(mean_err <= 1.25 * bound)), (
            f"Lemma-1 bound violated: {mean_err} vs {bound}")

    def test_error_decreases_with_r(self):
        key = jax.random.PRNGKey(7)
        kx, kw, ks = jax.random.split(key, 3)
        x = _rand(kx, (32, 256))
        w = _rand(kw, (256, 64))
        probs = amm.block_probs(w, block=32)
        exact = x @ w

        def mean_err(r):
            def one(k):
                idx, inv = amm.draw_block_samples(k, probs, r)
                return amm.sampled_matmul(x, w, idx, inv, block=32)
            trials = jax.vmap(one)(jax.random.split(ks, 128))
            return float(jnp.mean(jnp.linalg.norm(trials - exact[None],
                                                  axis=(-2, -1))))

        errs = [mean_err(r) for r in (1, 4, 16)]
        assert errs[0] > errs[1] > errs[2]


class TestFlopsAccounting:
    def test_exact_flops(self):
        assert amm.exact_flops(10, 64, 32) == 2 * 10 * 64 * 32

    def test_sampled_flops_scalar_and_array(self):
        assert amm.sampled_flops(4, 32, block=16) == 2 * 4 * 16 * 32
        arr = jnp.asarray([1, 2, 3])
        assert int(amm.sampled_flops(arr, 8, block=16)) == 2 * 6 * 16 * 8
