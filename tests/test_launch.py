"""Launch-layer tests: HLO collective parser, input specs, roofline math.
(The 512-device dry-run itself runs via launch/dryrun.py, not pytest.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, cells, get_config
from repro.launch import hlo_analysis
from repro.launch.dryrun import (_depth_overrides, _real_units, model_flops,
                                 n_params, roofline_terms)
from repro.launch.mesh import HW
from repro.launch.specs import input_specs

jax.config.update("jax_platform_name", "cpu")


class TestHloParser:
    HLO = """
  %add.1 = f32[4,4] add(%a, %b)
  %ag = bf16[16,4096,128]{2,1,0} all-gather(%x), replica_groups={...}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %rs = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) reduce-scatter(%p, %q)
  %cp = bf16[2,2]{1,0} collective-permute(%z)
  %a2a = f32[64,32]{1,0} all-to-all(%w)
"""

    def test_collective_bytes(self):
        st = hlo_analysis.collective_stats(self.HLO)
        assert st["all-gather"]["count"] == 1
        assert st["all-gather"]["bytes"] == 16 * 4096 * 128 * 2
        assert st["all-reduce"]["bytes"] == 1024 * 4
        assert st["reduce-scatter"]["bytes"] == 2 * 8 * 128 * 2
        assert st["collective-permute"]["bytes"] == 4 * 2
        assert st["all-to-all"]["bytes"] == 64 * 32 * 4
        total = sum(st[k]["bytes"] for k in hlo_analysis.COLLECTIVES)
        assert st["total_bytes"] == total

    def test_start_done_counted_once(self):
        hlo = """
  %s = bf16[8,8]{1,0} all-gather-start(%x)
  %d = bf16[8,8]{1,0} all-gather-done(%s)
"""
        st = hlo_analysis.collective_stats(hlo)
        assert st["all-gather"]["count"] == 1
        assert st["all-gather"]["bytes"] == 128

    def test_non_collective_ignored(self):
        st = hlo_analysis.collective_stats("%m = f32[4,4] dot(%a, %b)")
        assert st["total_bytes"] == 0


class TestInputSpecs:
    def test_all_cells_have_specs(self):
        for arch, shape in cells():
            cfg, kind, specs = input_specs(arch, shape)
            seq, batch, expect_kind = SHAPES[shape]
            assert kind == expect_kind
            if kind == "train":
                assert specs["tokens"].shape == (batch, seq)
                assert specs["labels"].shape == (batch, seq)
            elif kind == "prefill":
                assert specs["tokens"].shape == (batch, seq)
            else:
                tok, cache, t = specs
                assert tok.shape == (batch, 1)
                assert t.shape == ()
                assert len(jax.tree.leaves(cache)) > 0

    def test_vlm_and_audio_frontend_stubs(self):
        cfg, _, specs = input_specs("internvl2-1b", "train_4k")
        assert specs["patches"].shape == (256, cfg.n_patch_tokens,
                                          cfg.d_model)
        cfg, _, specs = input_specs("whisper-small", "train_4k")
        assert specs["frames"].shape == (256, cfg.encoder_len, cfg.d_model)

    def test_long_shape_only_for_subquadratic(self):
        cs = cells()
        long_archs = {a for a, s in cs if s == "long_500k"}
        assert long_archs == {"mamba2-2.7b", "recurrentgemma-9b"}
        # 10 archs x 3 shapes + 2 long cells
        assert len(cs) == 32

    def test_decode_cache_slots(self):
        cfg, _, (tok, cache, t) = input_specs("qwen3-32b", "decode_32k")
        k = cache["layers"]["k"]
        assert k.shape == (64, 128, 32768, 8, 128)
        # recurrentgemma long_500k: rolling window cache, not 512k slots
        cfg, _, (tok, cache, t) = input_specs("recurrentgemma-9b",
                                              "long_500k")
        attn_cache = cache["groups"]["pos2"]
        assert attn_cache["k"].shape[2] == cfg.window


class TestRooflineMath:
    def test_terms(self):
        res = {"flops": HW["peak_bf16_flops"],
               "bytes_accessed": HW["hbm_bw"] * 2,
               "collectives": {"total_bytes": HW["ici_bw"] * 3}}
        t = roofline_terms(res)
        assert t["t_compute"] == pytest.approx(1.0)
        assert t["t_memory"] == pytest.approx(2.0)
        assert t["t_collective"] == pytest.approx(3.0)
        assert t["bottleneck"] == "t_collective"

    def test_depth_overrides(self):
        cfg = get_config("recurrentgemma-9b")
        assert _real_units(cfg) == 12
        ov = _depth_overrides(cfg, 2)
        assert ov["n_layers"] == 2 * 3 + 2
        cfg = get_config("whisper-small")
        ov = _depth_overrides(cfg, 1)
        assert ov == {"n_layers": 1, "n_encoder_layers": 1}

    def test_param_counts_sane(self):
        c = n_params(get_config("qwen3-32b"))
        assert 30e9 < c["active_nonembed"] < 36e9
        c = n_params(get_config("granite-moe-1b-a400m"))
        assert c["active_nonembed"] < 0.8e9       # top-8/32 of experts
        assert c["total"] > 1.0e9

    def test_model_flops_train_vs_decode(self):
        f_train = model_flops(get_config("starcoder2-3b"), "train",
                              4096, 256)
        f_dec = model_flops(get_config("starcoder2-3b"), "decode",
                            32768, 128)
        assert f_train > f_dec * 1000
