"""Tests for the MCA schedule, tier routing, and mca_project policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (MCAConfig, amm, dispatch, error_bounds, mca_project,
                        schedule)

jax.config.update("jax_platform_name", "cpu")


class TestSchedule:
    def test_eq9_r_schedule(self):
        # sqrt(r) = n*maxA/alpha  ->  r = (n*maxA/alpha)^2
        n, d, alpha = 128, 768, 0.5
        colmax = jnp.asarray([1.0 / n, 0.01, 0.5, 1.0])
        r = schedule.r_cols_from_attention(colmax, n, alpha, d)
        expected = np.clip((n * np.asarray(colmax) / alpha) ** 2, 1, d)
        np.testing.assert_allclose(np.asarray(r), expected, rtol=1e-6)

    def test_r_clipped_to_d(self):
        r = schedule.r_cols_from_attention(jnp.asarray([1.0]), 4096, 0.1, 512)
        assert float(r[0]) == 512.0

    def test_tier_ladder_ends_exact(self):
        lad = schedule.tier_ladder(1024, 128, n_tiers=4)
        assert lad == (1, 2, 4, 8)
        assert lad[-1] == 1024 // 128
        lad2 = schedule.tier_ladder(256, 128, n_tiers=8)
        assert lad2 == (1, 2)   # ladder truncates at K

    def test_assign_tiers_conservative(self):
        lad = (1, 2, 4, 8)
        r = jnp.asarray([1, 2, 3, 4, 5, 8])
        t = schedule.assign_tiers(r, lad)
        # 3 -> tier with R=4, 5 -> tier with R=8 (round UP, never down)
        np.testing.assert_array_equal(np.asarray(t), [0, 1, 2, 2, 3, 3])

    def test_importance_from_attention(self):
        a = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0),
                                             (2, 4, 8, 8)), axis=-1)
        col = schedule.importance_from_attention(a)
        assert col.shape == (2, 8)
        ref = np.asarray(a).max(axis=(1, 2))
        np.testing.assert_allclose(np.asarray(col), ref, rtol=1e-6)


class TestCapacityRouting:
    def test_no_overflow_identity(self):
        tier = jnp.asarray([0, 1, 2, 2, 1, 0])
        imp = jnp.arange(6.0)
        out = dispatch.apply_capacity(tier, imp, caps=(6, 6, 6))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(tier))

    def test_overflow_demotes_lowest_importance(self):
        # four tokens want tier 2 but cap is 2 -> two lowest-importance demote
        tier = jnp.asarray([2, 2, 2, 2])
        imp = jnp.asarray([0.9, 0.1, 0.8, 0.2])
        out = np.asarray(dispatch.apply_capacity(tier, imp, caps=(4, 4, 2)))
        np.testing.assert_array_equal(out, [2, 1, 2, 1])

    def test_cascade_demotion_to_tier0(self):
        tier = jnp.asarray([2, 2, 2])
        imp = jnp.asarray([3.0, 2.0, 1.0])
        out = np.asarray(dispatch.apply_capacity(tier, imp, caps=(3, 1, 1)))
        np.testing.assert_array_equal(out, [2, 1, 0])


class TestTieredMatmul:
    def test_exact_tier_only_matches_dense(self):
        """All tokens in the exact tier -> bit-exact projection (no sampling)."""
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (12, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        lad = (1, 4)  # K = 4 with block 16
        tier = jnp.full((12,), 1, jnp.int32)
        imp = jnp.ones((12,))
        y = dispatch.tiered_mca_matmul(key, x, w, tier, imp, lad,
                                       caps=(12, 12), block=16)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=2e-4, atol=2e-4)

    def test_mixed_tiers_unbiased(self):
        kx = jax.random.PRNGKey(2)
        x = jax.random.normal(kx, (16, 128))
        w = jax.random.normal(jax.random.PRNGKey(3), (128, 32))
        lad = (1, 2, 8)
        tier = jnp.asarray([0, 1] * 8, jnp.int32)
        imp = jnp.linspace(0, 1, 16)

        def one(k):
            return dispatch.tiered_mca_matmul(k, x, w, tier, imp, lad,
                                              caps=(16, 16, 16), block=16)
        # 4096 trials: expected rel ~0.035 here, so 0.08 gives >2x margin
        # (1024 was 0.0998 at this seed — inside MC noise, not bias)
        trials = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(4), 4096))
        est = jnp.mean(trials, axis=0)
        rel = float(jnp.linalg.norm(est - x @ w) / jnp.linalg.norm(x @ w))
        assert rel < 0.08, rel


class TestPerTokenMatmul:
    def test_full_r_exact(self):
        """r_j = K for every token makes counts a multinomial with mean cover;
        bias check via trial mean."""
        x = jax.random.normal(jax.random.PRNGKey(5), (8, 64))
        w = jax.random.normal(jax.random.PRNGKey(6), (64, 16))

        def one(k):
            return dispatch.per_token_mca_matmul(
                k, x, w, jnp.full((8,), 4, jnp.int32), block=16)
        trials = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(7), 2048))
        est = jnp.mean(trials, axis=0)
        rel = float(jnp.linalg.norm(est - x @ w) / jnp.linalg.norm(x @ w))
        assert rel < 0.05, rel

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_per_token_lemma1(self, seed):
        block, kb, f, n = 16, 8, 24, 32
        d = block * kb
        key = jax.random.PRNGKey(seed)
        kx, kw, kr, ks = jax.random.split(key, 4)
        x = jax.random.normal(kx, (n, d))
        w = jax.random.normal(kw, (d, f))
        r = jax.random.randint(kr, (n,), 1, kb + 1)

        def one(k):
            return dispatch.per_token_mca_matmul(k, x, w, r, block=block)
        trials = jax.vmap(one)(jax.random.split(ks, 256))
        err = jnp.mean(jnp.linalg.norm(trials - (x @ w)[None], axis=-1), 0)
        bound = error_bounds.lemma1_bound(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(w), r)
        assert bool(jnp.all(err <= 1.25 * bound))


class TestMcaProject:
    def _setup(self, n=32, d=128, f=64, seq=32):
        kx, kw, ki = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(kx, (n, d))
        w = jax.random.normal(kw, (d, f)) / np.sqrt(d)
        imp = jax.random.uniform(ki, (n,), minval=0.0, maxval=1.0)
        return x, w, imp

    def test_disabled_is_exact(self):
        x, w, imp = self._setup()
        cfg = MCAConfig(enabled=False)
        y, stats = mca_project(jax.random.PRNGKey(1), x, w, imp, 32, cfg, "v_proj")
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=2e-4, atol=2e-4)
        assert stats["mca_flops"] == stats["exact_flops"]

    def test_inactive_site_is_exact(self):
        x, w, imp = self._setup()
        cfg = MCAConfig(enabled=True, sites=("o_proj",))
        y, stats = mca_project(jax.random.PRNGKey(1), x, w, imp, 32, cfg, "v_proj")
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=2e-4, atol=2e-4)

    def test_enabled_reduces_flops(self):
        x, w, imp = self._setup()
        # low importance everywhere -> most tokens land in cheap tiers
        imp = imp * 0.01
        cfg = MCAConfig(enabled=True, alpha=0.5, block=16, sites=("v_proj",))
        y, stats = mca_project(jax.random.PRNGKey(1), x, w, imp, 32, cfg, "v_proj")
        assert y.shape == (32, 64)
        assert float(stats["mca_flops"]) < stats["exact_flops"]
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_alpha_zero_limit_is_high_precision(self):
        """alpha -> 0 pushes every token to the exact tier (r = d)."""
        x, w, imp = self._setup()
        cfg = MCAConfig(enabled=True, alpha=1e-6, block=16, sites=("v_proj",),
                        capacity_fracs=(1.0, 1.0, 1.0, 1.0))
        y, stats = mca_project(jax.random.PRNGKey(1), x, w, imp, 32, cfg, "v_proj")
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=2e-4, atol=2e-4)
        assert int(stats["tier_hist"][-1]) == 32

    def test_per_token_mode(self):
        x, w, imp = self._setup()
        cfg = MCAConfig(enabled=True, alpha=0.4, block=16, mode="per_token",
                        sites=("v_proj",))
        y, stats = mca_project(jax.random.PRNGKey(1), x, w, imp, 32, cfg, "v_proj")
        assert y.shape == (32, 64)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_batched_input(self):
        x, w, imp = self._setup()
        xb = x.reshape(2, 16, 128)
        impb = imp.reshape(2, 16)
        cfg = MCAConfig(enabled=True, alpha=0.4, block=16, sites=("v_proj",))
        y, _ = mca_project(jax.random.PRNGKey(1), xb, w, impb, 16, cfg, "v_proj")
        assert y.shape == (2, 16, 64)

    def test_theorem2_bound_end_to_end(self):
        """E||Ytilde - Y|| <= alpha * beta * ||W||_F (Eq. 10), per output row."""
        n, d, f = 24, 128, 64
        kq, kx, kw = jax.random.split(jax.random.PRNGKey(9), 3)
        x = jax.random.normal(kx, (n, d))
        w = jax.random.normal(kw, (d, f)) / np.sqrt(d)
        attn = jax.nn.softmax(
            jax.random.normal(kq, (n, n)) * 2.0, axis=-1)
        colmax = jnp.max(attn, axis=0)
        alpha = 0.4
        cfg = MCAConfig(enabled=True, alpha=alpha, block=16,
                        mode="per_token", sites=("v_proj",))

        def one(k):
            h, _ = mca_project(k, x, w, colmax, n, cfg, "v_proj")
            return attn @ h
        trials = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(10), 256))
        y = attn @ (x @ w)
        err = jnp.mean(jnp.linalg.norm(trials - y[None], axis=-1), axis=0)
        beta = error_bounds.beta_of(x)
        bound = error_bounds.theorem2_mean_bound(alpha, beta,
                                                 jnp.linalg.norm(w))
        assert bool(jnp.all(err <= 1.25 * bound)), (
            f"max err {float(err.max())} vs bound {float(bound)}")
