"""Suite-wide test environment.

- Pins JAX to the CPU backend before any backend is initialized; the main
  pytest process must keep seeing exactly ONE device (the 8-device SPMD
  tests run in subprocesses that set --xla_force_host_platform_device_count
  themselves — see tests/test_distributed.py).
- Scrubs an inherited XLA_FLAGS device-count override for the same reason.
- Seeds Python/NumPy PRNGs per test and provides a fixed JAX key fixture so
  the Monte-Carlo tests are deterministic run-to-run.
- Installs a minimal ``hypothesis`` shim when the real package is missing
  (the CI image does not ship it; no new deps may be installed).
"""
import importlib.util
import os
import pathlib
import random
import sys

# ---- hypothesis fallback (must run before test modules import it) ----
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_shim.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod

# ---- single-device CPU backend for the main process ----
if "--xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS",
                                                              ""):
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in os.environ["XLA_FLAGS"].split()
        if not f.startswith("--xla_force_host_platform_device_count"))

import jax  # noqa: E402  (after the env scrub, before device init)
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

SEED = 0


@pytest.fixture(autouse=True)
def _deterministic_seeds():
    """Fixed host-side PRNG state per test (JAX keys are explicit)."""
    random.seed(SEED)
    np.random.seed(SEED)
    yield


@pytest.fixture
def rng_key():
    """The suite's fixed base PRNG key; split, never reuse raw."""
    return jax.random.PRNGKey(SEED)
