"""Suite-wide test environment.

- Pins JAX to the CPU backend before any backend is initialized; the main
  pytest process must keep seeing exactly ONE device (the 8-device SPMD
  tests run in subprocesses that set --xla_force_host_platform_device_count
  themselves — see tests/test_distributed.py).
- Scrubs an inherited XLA_FLAGS device-count override for the same reason.
- Seeds Python/NumPy PRNGs per test and provides a fixed JAX key fixture so
  the Monte-Carlo tests are deterministic run-to-run.
- Installs a minimal ``hypothesis`` shim when the real package is missing
  (the CI image does not ship it; no new deps may be installed).
- Per-test timeout guard (SIGALRM shim, no pytest-timeout dependency): a
  hung collective / deadlocked queue fails its test in minutes instead of
  stalling the whole job for hours.  Default 600s; override per test with
  ``@pytest.mark.timeout(seconds)`` or the ``REPRO_TEST_TIMEOUT_S`` env
  var.  Best-effort: a hang inside non-cooperative native code may not be
  interruptible, and on platforms without SIGALRM the guard is a no-op.
"""
import importlib.util
import os
import pathlib
import random
import signal
import sys
import threading

# ---- hypothesis fallback (must run before test modules import it) ----
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_shim.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod

# ---- single-device CPU backend for the main process ----
if "--xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS",
                                                              ""):
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in os.environ["XLA_FLAGS"].split()
        if not f.startswith("--xla_force_host_platform_device_count"))

import jax  # noqa: E402  (after the env scrub, before device init)
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

SEED = 0


@pytest.fixture(autouse=True)
def _deterministic_seeds():
    """Fixed host-side PRNG state per test (JAX keys are explicit)."""
    random.seed(SEED)
    np.random.seed(SEED)
    yield


@pytest.fixture
def rng_key():
    """The suite's fixed base PRNG key; split, never reuse raw."""
    return jax.random.PRNGKey(SEED)


# ---- per-test timeout guard (shim; see module docstring) ----
DEFAULT_TEST_TIMEOUT_S = float(os.environ.get("REPRO_TEST_TIMEOUT_S", "600"))


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    can_alarm = (hasattr(signal, "SIGALRM")
                 and threading.current_thread() is threading.main_thread())
    marker = item.get_closest_marker("timeout")
    timeout = float(marker.args[0]) if (marker and marker.args) \
        else DEFAULT_TEST_TIMEOUT_S
    if not can_alarm or timeout <= 0:
        yield
        return

    def _on_alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded the {timeout:.0f}s per-test guard "
            "(hung collective / deadlock?)")

    old = signal.signal(signal.SIGALRM, _on_alarm)
    signal.setitimer(signal.ITIMER_REAL, timeout)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)
