"""Substrate tests: optimizer, data pipeline, checkpointing, trainer
fault-tolerance, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.data import MemmapLM, Prefetcher, SyntheticLM, write_token_file
from repro.dist import compress
from repro.models import build_model, reduced
from repro.optim import adamw
from repro.train import Trainer, TrainerConfig, make_train_step

jax.config.update("jax_platform_name", "cpu")


class TestAdamW:
    def test_quadratic_convergence(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=10.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw.init_state(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw.apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_clip_norm(self):
        grads = {"a": jnp.asarray([3.0, 4.0])}
        clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
        np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
        np.testing.assert_allclose(
            float(adamw.global_norm(clipped)), 1.0, rtol=1e-5)

    def test_schedule_warmup_and_decay(self):
        sch = adamw.cosine_schedule(warmup=10, total=100)
        assert float(sch(jnp.asarray(5))) == pytest.approx(0.5, abs=1e-6)
        assert float(sch(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-6)
        assert float(sch(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)

    def test_accumulation_matches_full_batch(self):
        cfg = reduced(get_config("starcoder2-3b"), n_layers=1)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        data = SyntheticLM(cfg.vocab_size, 16, 8, seed=1)
        batch = jax.tree.map(jnp.asarray, data.batch(0))

        def loss_fn(p, b, k):
            return model.loss(p, b, None)

        (l1, _), g1 = adamw.accumulate_gradients(loss_fn, params, batch, 1)
        (l4, _), g4 = adamw.accumulate_gradients(loss_fn, params, batch, 4)
        np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-5)


class TestData:
    def test_deterministic_replay(self):
        d1 = SyntheticLM(100, 32, 8, seed=7)
        d2 = SyntheticLM(100, 32, 8, seed=7)
        b1, b2 = d1.batch(5), d2.batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_host_sharding_disjoint(self):
        full = SyntheticLM(100, 16, 8, seed=3, n_hosts=1, host_id=0)
        h0 = SyntheticLM(100, 16, 8, seed=3, n_hosts=2, host_id=0)
        h1 = SyntheticLM(100, 16, 8, seed=3, n_hosts=2, host_id=1)
        assert h0.batch(0)["tokens"].shape[0] == 4
        assert not np.array_equal(h0.batch(0)["tokens"],
                                  h1.batch(0)["tokens"])
        assert full.batch(0)["tokens"].shape[0] == 8

    def test_labels_are_next_tokens(self):
        d = SyntheticLM(100, 16, 4, seed=0)
        b = d.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_memmap_roundtrip(self, tmp_path):
        path = str(tmp_path / "tokens.bin")
        write_token_file(path, np.arange(10_000) % 97)
        d = MemmapLM(path, 97, 32, 4, seed=0)
        b = d.batch(3)
        assert b["tokens"].shape == (4, 32)
        assert b["tokens"].max() < 97
        b2 = MemmapLM(path, 97, 32, 4, seed=0).batch(3)
        np.testing.assert_array_equal(b["tokens"], b2["tokens"])

    def test_prefetcher(self):
        d = SyntheticLM(50, 8, 2, seed=0)
        pf = Prefetcher(d, depth=2)
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        assert (s0, s1) == (0, 1)
        np.testing.assert_array_equal(b0["tokens"], d.batch(0)["tokens"])
        pf.close()


class TestCheckpoint:
    def _tree(self):
        return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}

    def test_save_restore_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt.save(str(tmp_path), 3, tree)
        out = ckpt.restore(str(tmp_path), 3, jax.eval_shape(lambda: tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_and_gc(self, tmp_path):
        tree = self._tree()
        for s in (1, 2, 3, 4, 5):
            ckpt.save(str(tmp_path), s, tree, keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 5
        steps = sorted(os.listdir(tmp_path))
        assert len(steps) == 2

    def test_structure_mismatch_raises(self, tmp_path):
        ckpt.save(str(tmp_path), 1, self._tree())
        with pytest.raises(ckpt.StructureMismatchError,
                           match="structure mismatch"):
            ckpt.restore(str(tmp_path), 1, {"x": jnp.zeros((2,))})

    def test_async_checkpointer(self, tmp_path):
        c = ckpt.AsyncCheckpointer(str(tmp_path))
        c.save(7, self._tree())
        c.wait()
        assert ckpt.latest_step(str(tmp_path)) == 7

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore onto explicit (1-device) shardings — the reshard path."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tree = self._tree()
        ckpt.save(str(tmp_path), 1, tree)
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
        out = ckpt.restore(str(tmp_path), 1, jax.eval_shape(lambda: tree),
                           shardings=sh)
        assert out["a"].sharding == NamedSharding(mesh, P())


class TestTrainerFaultTolerance:
    def _setup(self, tmp_path, total_steps=6):
        cfg = reduced(get_config("starcoder2-3b"), n_layers=1,
                      vocab_size=128)
        model = build_model(cfg)
        data = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
        opt = adamw.AdamWConfig(lr=1e-3)
        step = jax.jit(make_train_step(model, opt))
        tcfg = TrainerConfig(total_steps=total_steps,
                             ckpt_dir=str(tmp_path / "ckpt"),
                             ckpt_every=2, log_every=100, watchdog_s=600)
        return model, opt, data, step, tcfg

    def test_loss_decreases(self, tmp_path):
        model, opt, data, step, tcfg = self._setup(tmp_path, total_steps=30)
        tr = Trainer(model, opt, data, step, tcfg)
        out = tr.run()
        first = np.mean([h["loss"] for h in out["history"][:5]])
        last = np.mean([h["loss"] for h in out["history"][-5:]])
        assert last < first, (first, last)

    def test_restart_resumes_exactly(self, tmp_path):
        """Kill after 6 steps, restart, verify identical final params to an
        uninterrupted 12-step run (deterministic data replay + ckpt)."""
        model, opt, data, step, tcfg = self._setup(tmp_path)
        tr1 = Trainer(model, opt, data, step, tcfg)      # runs 0..6
        tr1.run()
        tcfg2 = TrainerConfig(**{**tcfg.__dict__, "total_steps": 12})
        tr2 = Trainer(model, opt, data, step, tcfg2)     # resumes at 6
        assert tr2.start_step == 6
        out2 = tr2.run()
        assert out2["steps"] == 6

        # uninterrupted reference
        import shutil
        shutil.rmtree(tcfg.ckpt_dir)
        tr3 = Trainer(model, opt, data, step, tcfg2)
        assert tr3.start_step == 0
        tr3.run()
        for a, b in zip(jax.tree.leaves(tr2.params),
                        jax.tree.leaves(tr3.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-5, atol=1e-6)

    def test_watchdog_fires_on_slow_step(self, tmp_path):
        import time
        model, opt, data, _, tcfg = self._setup(tmp_path, total_steps=1)
        tcfg.watchdog_s = 0.05

        def slow_step(params, opt_state, batch):
            time.sleep(0.2)
            opt_state = dict(opt_state)
            opt_state["count"] = opt_state["count"] + 1
            return params, opt_state, {"total_loss": jnp.zeros(())}

        tr = Trainer(model, opt, data, slow_step, tcfg)
        out = tr.run()
        assert out["watchdog_fired"] >= 1


class TestGradCompression:
    def test_quantize_roundtrip_error_small(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (128,))
        q, s = compress.quantize(g)
        deq = compress.dequantize(q, s)
        rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
        assert rel < 0.01

    def test_error_feedback_telescopes(self):
        """Sum of dequantized grads + final residual == sum of true grads
        (EF makes compression unbiased over time)."""
        key = jax.random.PRNGKey(1)
        grads = [jax.random.normal(jax.random.fold_in(key, i), (64,)) * 0.1
                 for i in range(20)]
        tree = {"w": jnp.zeros((64,))}
        err = compress.init_error_buffer(tree)
        total_sent = jnp.zeros((64,))
        for g in grads:
            q, s, err = compress.ef_compress_tree({"w": g}, err)
            total_sent = total_sent + compress.dequantize(q["w"], s["w"])
        true_sum = sum(grads)
        resid = err["w"]
        np.testing.assert_allclose(np.asarray(total_sent + resid),
                                   np.asarray(true_sum), rtol=1e-4,
                                   atol=1e-5)

    def test_shard_map_psum_compressed(self):
        """psum_compressed under shard_map on a 1-device mesh."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("data",))
        g = {"w": jnp.ones((8,))}
        e = compress.init_error_buffer(g)

        def f(g, e):
            return compress.psum_compressed(g, e, "data")

        out, new_e = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                               out_specs=(P(), P()))(g, e)
        np.testing.assert_allclose(np.asarray(out["w"]), 1.0, rtol=1e-2)
