"""Scientific-claim tests: the paper's core hypothesis — allocating samples
by attention mass beats uniform allocation at equal FLOPs budget."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dispatch, schedule

jax.config.update("jax_platform_name", "cpu")


def _concentrated_attention(key, n, hot_frac=0.1, temp=8.0):
    """Softmax attention where ~hot_frac of keys receive most mass."""
    scores = jax.random.normal(key, (n, n))
    hot = jax.random.bernoulli(jax.random.fold_in(key, 1), hot_frac, (n,))
    scores = scores + jnp.where(hot, temp, 0.0)[None, :]
    return jax.nn.softmax(scores, axis=-1)


class TestAttentionDrivenAllocation:
    def test_eq9_beats_uniform_at_equal_budget(self):
        """E||Y_tilde - Y|| with Eq.9 allocation < uniform allocation using
        the SAME total sample count — the reason MCA works."""
        n, d, f, block = 64, 256, 64, 16
        key = jax.random.PRNGKey(0)
        ka, kx, kw, ks = jax.random.split(key, 4)
        attn = _concentrated_attention(ka, n)
        x = jax.random.normal(kx, (n, d))
        w = jax.random.normal(kw, (d, f)) / np.sqrt(d)
        y = attn @ (x @ w)
        kb = d // block

        colmax = jnp.max(attn, axis=0)
        r_eq9 = schedule.r_blocks_from_cols(
            schedule.r_cols_from_attention(colmax, n, 0.3, d), block)
        r_eq9 = jnp.minimum(r_eq9, kb)
        budget = int(jnp.sum(r_eq9))
        r_unif = jnp.full((n,), max(budget // n, 1), jnp.int32)

        def err(r, trials=96):
            def one(k):
                h = dispatch.per_token_mca_matmul(k, x, w, r, block)
                return jnp.linalg.norm(attn @ h - y)
            keys = jax.random.split(ks, trials)
            return float(jnp.mean(jax.vmap(one)(keys)))

        e_eq9 = err(r_eq9)
        e_unif = err(r_unif)
        assert e_eq9 < e_unif, (e_eq9, e_unif, budget)
        # and the win is substantial on concentrated attention
        assert e_eq9 < 0.8 * e_unif, (e_eq9, e_unif)

    def test_error_shrinks_with_smaller_alpha(self):
        n, d, f, block = 32, 128, 32, 16
        key = jax.random.PRNGKey(1)
        ka, kx, kw, ks = jax.random.split(key, 4)
        attn = _concentrated_attention(ka, n)
        x = jax.random.normal(kx, (n, d))
        w = jax.random.normal(kw, (d, f)) / np.sqrt(d)
        y = attn @ (x @ w)
        colmax = jnp.max(attn, axis=0)

        def err(alpha):
            r = schedule.r_blocks_from_cols(
                schedule.r_cols_from_attention(colmax, n, alpha, d), block)
            def one(k):
                h = dispatch.per_token_mca_matmul(k, x, w, r, block)
                return jnp.linalg.norm(attn @ h - y)
            return float(jnp.mean(jax.vmap(one)(jax.random.split(ks, 64))))

        errs = [err(a) for a in (0.1, 0.4, 1.0)]
        assert errs[0] <= errs[1] <= errs[2] * 1.05, errs

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), alpha=st.floats(0.1, 1.0))
    def test_r_monotone_in_attention(self, seed, alpha):
        """More attention mass on a key never lowers its sample budget."""
        key = jax.random.PRNGKey(seed)
        cm = jax.random.uniform(key, (32,), minval=1e-4, maxval=1.0)
        cm2 = jnp.minimum(cm * 1.5, 1.0)
        r1 = schedule.r_cols_from_attention(cm, 128, alpha, 512)
        r2 = schedule.r_cols_from_attention(cm2, 128, alpha, 512)
        assert bool(jnp.all(r2 >= r1))

    def test_hot_keys_get_exact_compute(self):
        """Keys with high colmax must land in the exact tier (error 0)."""
        n, d, block = 64, 256, 16
        colmax = jnp.full((n,), 1.0 / n).at[:4].set(0.9)
        r = schedule.r_cols_from_attention(colmax, n, 0.2, d)
        assert bool(jnp.all(r[:4] == d))       # hot keys -> exact
        assert float(r[4:].max()) < d          # cold keys -> sampled
