"""Regression tests for kernel dispatch (repro.kernels.ops).

Covers three bugs:
  * flash_attention / attn_colmax dropped the causal *diagonal offset* for
    rectangular (sq < skv) shapes — decode-style suffix queries attended to
    the wrong triangle;
  * mca_matmul_ragged crashed (kernel-side assert) whenever the row-tile
    count implied a tile size below block_m, instead of falling back;
  * the wrappers passed the caller's block sizes through unclamped, so the
    dispatch decision and the kernel's own clamping could disagree.

Also checks that every dispatch records kernel/fallback counters in the
repro.obs registry.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import amm
from repro.kernels import (attn_colmax, flash_attention, mca_matmul,
                           mca_matmul_ragged)
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def _qkv(b, hq, hkv, sq, skv, dh, seed=0):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (b, hq, sq, dh))
    k = jax.random.normal(kk, (b, hkv, skv, dh))
    v = jax.random.normal(kv, (b, hkv, skv, dh))
    return q, k, v


# --------------------------------------------- causal offset (sq < skv)
@pytest.mark.parametrize("sq,skv", [(64, 128), (64, 192), (128, 256)])
def test_flash_attention_causal_rectangular(sq, skv):
    """Suffix queries (kv history longer than the query span) must mask
    against the shifted diagonal, matching the reference oracle."""
    q, k, v = _qkv(1, 2, 2, sq, skv, 32)
    scale = 1.0 / np.sqrt(32)
    out, lse = flash_attention(q, k, v, scale=scale, causal=True,
                               block_q=64, block_k=64)
    ref_out, ref_lse = kref.ref_attention(q, k, v, scale=scale, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(ref_lse),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("sq,skv", [(64, 128), (128, 256)])
def test_attn_colmax_causal_rectangular(sq, skv):
    q, k, v = _qkv(1, 2, 2, sq, skv, 32, seed=1)
    scale = 1.0 / np.sqrt(32)
    _, lse = kref.ref_attention(q, k, v, scale=scale, causal=True)
    cm = attn_colmax(q, k, lse, scale=scale, causal=True,
                     block_q=64, block_k=64)
    ref_cm = jnp.max(kref.ref_colmax(q, k, lse, scale=scale, causal=True),
                     axis=1)
    np.testing.assert_allclose(np.asarray(cm), np.asarray(ref_cm),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------- ragged tile fallback
def test_mca_matmul_ragged_small_row_tiles():
    """m=192 with 3 row tiles implies bm=64 < block_m=128: must not crash
    and must match the eager reference."""
    m, d, f, block, rmax = 192, 256, 128, 64, 3
    kx, kw, kr, ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(kx, (m, d))
    w = jax.random.normal(kw, (d, f))
    m_tiles = 3
    r_tile = jax.random.randint(kr, (m_tiles,), 1, rmax + 1)
    probs = amm.block_probs(w, block)
    idx = jax.random.categorical(ks, jnp.log(probs), shape=(m_tiles, rmax))
    inv_rp = 1.0 / (r_tile[:, None] * probs[idx])
    out = mca_matmul_ragged(x, w, r_tile, idx, inv_rp, block=block,
                            block_m=128)
    ref = kref.ref_mca_matmul_ragged(x, w, np.asarray(r_tile), idx, inv_rp,
                                     block, m // m_tiles)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_mca_matmul_ragged_fallback_traceable_under_jit():
    """The fallback path must not concretize r_tile (jit-safe)."""
    m, d, f, block = 96, 128, 64, 32
    kx, kw, ks = jax.random.split(jax.random.PRNGKey(4), 3)
    x = jax.random.normal(kx, (m, d))
    w = jax.random.normal(kw, (d, f))
    m_tiles, rmax = 3, 2
    r_tile = jnp.asarray([1, 2, 2], jnp.int32)
    probs = amm.block_probs(w, block)
    idx = jax.random.categorical(ks, jnp.log(probs), shape=(m_tiles, rmax))
    inv_rp = 1.0 / (r_tile[:, None] * probs[idx])

    fn = jax.jit(lambda x, w, r, i, p: mca_matmul_ragged(
        x, w, r, i, p, block=block, block_m=128))
    out = fn(x, w, r_tile, idx, inv_rp)
    ref = kref.ref_mca_matmul_ragged(x, w, np.asarray(r_tile), idx, inv_rp,
                                     block, m // m_tiles)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------- clamped block sizes
def test_mca_matmul_clamps_blocks_to_shape():
    """m,f smaller than the requested block sizes must still take the
    kernel path (clamped), not silently mis-dispatch."""
    m, d, f, block, r = 64, 256, 64, 64, 3
    kx, kw, ks = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(kx, (m, d))
    w = jax.random.normal(kw, (d, f))
    probs = amm.block_probs(w, block)
    idx, inv_rp = amm.draw_block_samples(ks, probs, r)
    with obs.scoped() as reg:
        out = mca_matmul(x, w, idx, inv_rp, block=block,
                         block_m=128, block_f=128)
        assert reg.counter("kernels.mca_matmul.kernel_calls").value == 1
        assert reg.counter("kernels.mca_matmul.fallback_calls").value == 0
    ref = kref.ref_mca_matmul_fixed(x, w, idx, inv_rp, block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_dispatch_counters_recorded():
    q, k, v = _qkv(1, 2, 2, 64, 64, 32, seed=6)
    scale = 1.0 / np.sqrt(32)
    with obs.scoped() as reg:
        flash_attention(q, k, v, scale=scale, causal=True,
                        block_q=64, block_k=64)
        assert reg.counter(
            "kernels.flash_attention.kernel_calls").value == 1
        # skv=48 not divisible by the clamped bk: must fall back and say so
        flash_attention(q, k[:, :, :48], v[:, :, :48], scale=scale,
                        causal=False, block_q=64, block_k=32)
        assert reg.counter(
            "kernels.flash_attention.fallback_calls").value == 1
