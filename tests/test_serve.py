"""Serving engine tests: generation consistency + continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, reduced
from repro.serve import ContinuousBatcher, Engine, Request

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced(get_config("starcoder2-3b"), n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_size=2, max_len=64)
    return cfg, model, params, eng


def test_generate_shapes_and_determinism(engine_setup):
    cfg, model, params, eng = engine_setup
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8))
    out1 = eng.generate(prompts, max_new=6)
    out2 = eng.generate(prompts, max_new=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.max() < cfg.vocab_size


def test_generate_matches_stepwise_forward(engine_setup):
    """Greedy generation equals repeated full-forward argmax (KV-cache
    correctness across multiple decode steps)."""
    cfg, model, params, eng = engine_setup
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 8))
    gen = eng.generate(prompts, max_new=4)

    from repro.models.api import _logits
    toks = jnp.asarray(prompts, jnp.int32)
    for i in range(4):
        hidden, _, _ = model.forward_hidden(params, {"tokens": toks})
        nxt = jnp.argmax(_logits(params, cfg, hidden[:, -1:])
                         [..., :cfg.vocab_size], axis=-1)
        np.testing.assert_array_equal(np.asarray(nxt)[:, 0], gen[:, i])
        toks = jnp.concatenate([toks, nxt.astype(jnp.int32)], axis=1)


def test_continuous_batcher_serves_all(engine_setup):
    cfg, model, params, eng = engine_setup
    rng = np.random.default_rng(2)
    batcher = ContinuousBatcher(eng)
    for uid in range(5):
        batcher.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab_size, 8),
                               max_new=4))
    done = batcher.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(v) == 4 for v in done.values())


def test_batcher_ragged_prompt_parity(engine_setup):
    """Regression: ragged prompts used to be left-padded with mode="edge",
    replicating the first token as real context — a short prompt batched
    with a long one generated different tokens than it would alone.  With
    pad-id padding + position offsets the outputs must match exactly."""
    cfg, model, params, eng = engine_setup
    rng = np.random.default_rng(3)
    long_p = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
    short_p = rng.integers(1, cfg.vocab_size, 5).astype(np.int32)

    def solo(p, max_new):
        return eng.generate(np.stack([p, p]), max_new)[0].tolist()

    want = {0: solo(long_p, 6), 1: solo(short_p, 6)}
    batcher = ContinuousBatcher(eng)
    batcher.submit(Request(uid=0, prompt=long_p, max_new=6))
    batcher.submit(Request(uid=1, prompt=short_p, max_new=6))
    done = batcher.run()
    assert done[0] == want[0], "long prompt drifted under batching"
    assert done[1] == want[1], "short (padded) prompt != solo generation"


def test_generate_explicit_prompt_lens_matches_solo(engine_setup):
    """Engine.generate with prompt_lens on a pre-padded batch gives the
    same rows as each prompt generated unpadded."""
    cfg, model, params, eng = engine_setup
    rng = np.random.default_rng(4)
    a = rng.integers(1, cfg.vocab_size, 10).astype(np.int32)
    b = rng.integers(1, cfg.vocab_size, 7).astype(np.int32)
    s = 10
    padded = np.stack([a, np.pad(b, (s - len(b), 0))])
    out = eng.generate(padded, max_new=5,
                       prompt_lens=np.asarray([len(a), len(b)]))
    solo_b = eng.generate(np.stack([b, b]), max_new=5)[0]
    np.testing.assert_array_equal(out[1], solo_b)


def test_engine_records_obs_metrics(engine_setup):
    from repro import obs
    cfg, model, params, eng = engine_setup
    rng = np.random.default_rng(5)
    with obs.scoped() as reg:
        batcher = ContinuousBatcher(eng)
        for uid in range(3):
            batcher.submit(Request(
                uid=uid, prompt=rng.integers(1, cfg.vocab_size, 6),
                max_new=4))
        batcher.run()
        snap = reg.snapshot()
    assert snap["counters"]["serve.requests_completed"] == 3
    assert snap["counters"]["serve.waves"] == 2          # batch=2 -> 2 waves
    # 3 real requests x 4 tokens: the dummy slot padding wave 2 is excluded
    assert snap["counters"]["serve.generated_tokens"] == 3 * 4
    assert snap["histograms"]["serve.prefill_seconds"]["count"] == 2
    assert snap["histograms"]["serve.wave_seconds"]["count"] == 2
    assert snap["gauges"]["serve.slot_utilization"] == 0.5   # last wave 1/2
    # MCA disabled: stats still flow, reduction is exactly 1x
    assert snap["gauges"]["serve.flops_reduction"] == 1.0


def test_engine_mca_stats_tier_occupancy():
    """With MCA on, the engine surfaces tier occupancy + flops reduction."""
    from repro import obs
    from repro.core.policy import MCAConfig
    cfg = reduced(get_config("starcoder2-3b"), n_layers=2, vocab_size=128,
                  mca=MCAConfig(enabled=True, alpha=0.4, block=16,
                                sites=("v_proj",)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_size=2, max_len=32, mca_enabled=True)
    prompts = np.random.default_rng(6).integers(1, cfg.vocab_size, (2, 8))
    with obs.scoped() as reg:
        eng.generate(prompts, max_new=3)
        snap = reg.snapshot()
    assert snap["gauges"]["serve.flops_reduction"] > 1.0
    occ = [v for k, v in snap["counters"].items()
           if k.startswith("serve.tier_occupancy.t")]
    assert occ and sum(occ) > 0


# ---------------------------------------------------------------- per-slot
def test_slot_batcher_parity_vs_solo_and_wave(engine_setup):
    """The tentpole contract: per-slot insertion generates token-identical
    output to (a) each request run alone and (b) the wave batcher, for
    ragged prompts with different max_new — nothing about sharing the
    decode cache may leak between slots."""
    from repro.serve import SlotBatcher
    cfg, model, params, eng = engine_setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 4, 12, 6, 5)]
    max_news = [5, 7, 3, 6, 4]

    def solo(p, max_new):
        return eng.generate(np.stack([p, p]), max_new)[0].tolist()

    want = {i: solo(p, m) for i, (p, m) in enumerate(zip(prompts,
                                                         max_news))}
    sb = SlotBatcher(eng, check_every=3)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        assert sb.submit(Request(uid=i, prompt=p, max_new=m)) == "queued"
    got = sb.run()
    for i in want:
        assert sb.status[i] == "ok"
        assert got[i] == want[i], f"slot-batched req {i} != solo"

    wave = ContinuousBatcher(eng)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        wave.submit(Request(uid=100 + i, prompt=p, max_new=m))
    wdone = wave.run()
    for i in want:
        assert wdone[100 + i] == got[i], f"wave vs per-slot drift, req {i}"


def test_slot_batcher_metrics(engine_setup):
    """Insertion counters: one batch=1 prefill per request, tokens saved
    vs the wave batcher accounted, idle-slot steps and live-slot
    utilization agree."""
    from repro import obs
    from repro.serve import SlotBatcher
    cfg, model, params, eng = engine_setup
    rng = np.random.default_rng(8)
    with obs.scoped() as reg:
        sb = SlotBatcher(eng, check_every=4)
        for uid in range(3):
            sb.submit(Request(uid=uid,
                              prompt=rng.integers(1, cfg.vocab_size, 6),
                              max_new=4))
        done = sb.run()
        snap = reg.snapshot()
    assert all(len(done[i]) == 4 for i in range(3))
    c = snap["counters"]
    assert c["serve.insertions"] == 3                 # one prefill each
    assert c["serve.requests_completed"] == 3
    assert c["serve.generated_tokens"] == 3 * 4
    # prompts pad to the 8-bucket; the third insertion happens while one
    # slot is still occupied, so >= one occupied pad is "saved" prefill
    assert c["serve.prefill_tokens"] == 3 * 8
    assert c["serve.prefill_tokens_saved"] >= 8
    util = snap["gauges"]["serve.slot_utilization"]
    idle = c.get("serve.slot_idle_steps", 0)
    assert 0 < util <= 1
    # utilization + idle fraction account for every slot-step burst
    hist = snap["histograms"]["serve.decode_step_seconds"]
    total = hist["count"] * 4 * eng.batch
    assert abs(util - (total - idle) / total) < 1e-9


def test_slot_batcher_eos_and_deadline(engine_setup):
    """EOS stops a slot early (device-side countdown) and an expired
    deadline times the request out without touching other slots."""
    from repro.serve import SlotBatcher
    cfg, model, params, eng = engine_setup
    rng = np.random.default_rng(9)
    p = rng.integers(1, cfg.vocab_size, 6).astype(np.int32)
    ref = eng.generate(np.stack([p, p]), 8)[0].tolist()
    eos = ref[2]                  # force EOS at the 3rd generated token
    sb = SlotBatcher(eng, check_every=3, eos_id=eos)
    sb.submit(Request(uid=0, prompt=p, max_new=8))
    done = sb.run()
    assert done[0] == ref[:3], "generation must stop at (and include) EOS"

    sb2 = SlotBatcher(eng, check_every=3)
    sb2.submit(Request(uid=1, prompt=p, max_new=8, deadline_s=-1.0))
    out = sb2.run()
    assert sb2.status[1] == "timeout" and 1 not in out
