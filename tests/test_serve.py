"""Serving engine tests: generation consistency + continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, reduced
from repro.serve import ContinuousBatcher, Engine, Request

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced(get_config("starcoder2-3b"), n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_size=2, max_len=64)
    return cfg, model, params, eng


def test_generate_shapes_and_determinism(engine_setup):
    cfg, model, params, eng = engine_setup
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8))
    out1 = eng.generate(prompts, max_new=6)
    out2 = eng.generate(prompts, max_new=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.max() < cfg.vocab_size


def test_generate_matches_stepwise_forward(engine_setup):
    """Greedy generation equals repeated full-forward argmax (KV-cache
    correctness across multiple decode steps)."""
    cfg, model, params, eng = engine_setup
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 8))
    gen = eng.generate(prompts, max_new=4)

    from repro.models.api import _logits
    toks = jnp.asarray(prompts, jnp.int32)
    for i in range(4):
        hidden, _, _ = model.forward_hidden(params, {"tokens": toks})
        nxt = jnp.argmax(_logits(params, cfg, hidden[:, -1:])
                         [..., :cfg.vocab_size], axis=-1)
        np.testing.assert_array_equal(np.asarray(nxt)[:, 0], gen[:, i])
        toks = jnp.concatenate([toks, nxt.astype(jnp.int32)], axis=1)


def test_continuous_batcher_serves_all(engine_setup):
    cfg, model, params, eng = engine_setup
    rng = np.random.default_rng(2)
    batcher = ContinuousBatcher(eng)
    for uid in range(5):
        batcher.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab_size, 8),
                               max_new=4))
    done = batcher.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(v) == 4 for v in done.values())
