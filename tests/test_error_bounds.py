"""Property tests for core/error_bounds.py (Lemma 1 / Theorem 2).

Uses ``hypothesis`` (the real package, or the deterministic shim installed
by conftest.py when it is absent) to check the bound as a FUNCTION, then
one empirical check that the paper's inequality — with the W-only sampling
marginal p(b) ∝ ||W[b]||², not the optimal joint marginal — actually holds
for the block estimator in core/amm.py (see the caveat in the
error_bounds.py module docstring).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import amm, error_bounds

jax.config.update("jax_platform_name", "cpu")


class TestLemma1Function:
    @settings(max_examples=40, deadline=None)
    @given(xn=st.floats(0.0, 1e3), wf=st.floats(0.0, 1e3),
           r1=st.integers(1, 4096), r2=st.integers(1, 4096))
    def test_monotone_non_increasing_in_r(self, xn, wf, r1, r2):
        """More samples never weakens the guarantee: r2 >= r1 implies
        bound(r2) <= bound(r1)."""
        lo, hi = sorted((r1, r2))
        b_lo = float(error_bounds.lemma1_bound(
            jnp.float32(xn), jnp.float32(wf), jnp.asarray(lo)))
        b_hi = float(error_bounds.lemma1_bound(
            jnp.float32(xn), jnp.float32(wf), jnp.asarray(hi)))
        assert b_hi <= b_lo + 1e-6 * max(1.0, b_lo)

    @settings(max_examples=20, deadline=None)
    @given(xn=st.floats(1e-3, 1e3), wf=st.floats(1e-3, 1e3),
           r=st.integers(1, 4096), c=st.floats(0.1, 10.0))
    def test_homogeneous_in_norms(self, xn, wf, r, c):
        """Bound scales linearly in ||X[j]|| and in ||W||_F."""
        b = float(error_bounds.lemma1_bound(
            jnp.float32(xn), jnp.float32(wf), jnp.asarray(r)))
        bc = float(error_bounds.lemma1_bound(
            jnp.float32(c * xn), jnp.float32(wf), jnp.asarray(r)))
        np.testing.assert_allclose(bc, c * b, rtol=1e-5)
        bw = float(error_bounds.lemma1_bound(
            jnp.float32(xn), jnp.float32(c * wf), jnp.asarray(r)))
        np.testing.assert_allclose(bw, c * b, rtol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), kblocks=st.integers(1, 16))
    def test_tight_at_full_sampling(self, seed, kblocks):
        """At full sampling the bound is the family's infimum over r in
        [1, K] — exactly ||X[j]|| ||W||_F / sqrt(K) — and the estimator that
        enumerates every block once (idx = 0..K-1, inv_rp = 1) has ZERO
        error, so full sampling saturates the guarantee trivially."""
        block, f, n = 16, 8, 4
        d = block * kblocks
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (n, d))
        w = jax.random.normal(kw, (d, f))
        xn = jnp.linalg.norm(x, axis=-1)
        wf = error_bounds.w_fro(w)
        rs = jnp.arange(1, kblocks + 1)
        bounds = error_bounds.lemma1_bound(xn[:, None], wf, rs[None, :])
        # infimum at r = K ...
        full = bounds[:, -1]
        assert bool(jnp.all(full <= jnp.min(bounds, axis=-1) + 1e-6))
        np.testing.assert_allclose(np.asarray(full),
                                   np.asarray(xn * wf / np.sqrt(kblocks)),
                                   rtol=1e-6)
        # ... and the deterministic full-enumeration estimator achieves 0
        idx = jnp.arange(kblocks, dtype=jnp.int32)
        est = amm.sampled_matmul(x, w, idx, jnp.ones((kblocks,)), block)
        err = jnp.linalg.norm(est - x @ w, axis=-1)
        assert bool(jnp.all(err <= 1e-3 * full + 1e-5))

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), alpha=st.floats(0.05, 1.0))
    def test_theorem2_is_attention_weighted_lemma1_under_eq9(self, seed,
                                                            alpha):
        """Theorem 2 is exactly the attention-weighted sum of Lemma-1 bounds
        under the Eq. 9 schedule: with sqrt(r_j) = n * maxA_j / alpha
        (unclipped) each column contributes maxA_j * lemma1(xn_j, wf, r_j)
        = alpha * xn_j * wf / n, so the sum over j collapses to
        alpha * beta * ||W||_F — Eq. 10 with no slack."""
        n, d, f = 32, 128, 16
        kx, kw, ka = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(kx, (n, d))
        w = jax.random.normal(kw, (d, f))
        colmax = jax.random.uniform(ka, (n,), minval=0.05, maxval=1.0)
        xn = jnp.linalg.norm(x, axis=-1)
        wf = error_bounds.w_fro(w)
        r = (n * colmax / alpha) ** 2         # Eq. 9, no [1, K] clipping
        weighted = colmax * error_bounds.lemma1_bound(xn, wf, r)
        lhs = float(jnp.sum(weighted))
        rhs = float(error_bounds.theorem2_mean_bound(
            alpha, error_bounds.beta_of(x), wf))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-5)

    def test_tail_bound_markov_relation(self):
        """Eq. 11 is Eq. 10 inflated by 1/delta (Markov)."""
        beta = jnp.float32(3.0)
        wf = jnp.float32(2.0)
        for delta in (0.5, 0.1, 0.01):
            tail = float(error_bounds.theorem2_tail_bound(0.4, beta, wf, delta))
            mean = float(error_bounds.theorem2_mean_bound(0.4, beta, wf))
            np.testing.assert_allclose(tail, mean / delta, rtol=1e-6)


class TestLemma1Empirical:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000), r=st.integers(1, 8))
    def test_bound_holds_for_w_marginal_sampling(self, seed, r):
        """The PAPER's inequality with p(b) ∝ ||W[b]||² (not the optimal
        joint marginal) holds empirically for the block estimator."""
        block, kb, f, n = 16, 8, 12, 16
        d = block * kb
        kx, kw, ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(kx, (n, d))
        w = jax.random.normal(kw, (d, f))
        probs = amm.block_probs(w, block)
        exact = x @ w

        def one(k):
            idx, inv_rp = amm.draw_block_samples(k, probs, r)
            return amm.sampled_matmul(x, w, idx, inv_rp, block)

        trials = jax.vmap(one)(jax.random.split(ks, 256))
        err = jnp.mean(jnp.linalg.norm(trials - exact[None], axis=-1), axis=0)
        bound = error_bounds.lemma1_bound(
            jnp.linalg.norm(x, axis=-1), error_bounds.w_fro(w),
            jnp.full((n,), r, jnp.float32))
        assert bool(jnp.all(err <= 1.25 * bound)), (
            float(jnp.max(err / bound)))
