"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness. The FULL configs are exercised
only via the dry-run (launch/dryrun.py, ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model, reduced

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32
ALL_ARCHS = [a for a in ARCHS]


def _batch(cfg, key):
    kt, kl, kp = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            kp, (B, cfg.n_patch_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            kp, (B, cfg.encoder_len, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ALL_ARCHS)
def arch_setup(request):
    arch = request.param
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    return arch, cfg, model, params, batch


class TestForward:
    def test_loss_finite(self, arch_setup):
        arch, cfg, model, params, batch = arch_setup
        loss, metrics = jax.jit(model.loss)(params, batch)
        assert np.isfinite(float(loss)), f"{arch}: loss not finite"
        assert float(loss) > 0.0
        # random init: loss should be near log(vocab)
        assert float(metrics["loss"]) < 2 * np.log(cfg.vocab_size)

    def test_train_step_updates(self, arch_setup):
        arch, cfg, model, params, batch = arch_setup

        def loss_fn(p):
            return model.loss(p, batch)[0]

        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0, \
            f"{arch}: grad norm {gnorm}"
        # one SGD step lowers loss on the same batch
        lr = 0.1
        new_params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        loss2 = jax.jit(model.loss)(new_params, batch)[0]
        assert float(loss2) < float(loss), f"{arch}: {loss2} !< {loss}"


class TestMCASmoke:
    def test_loss_with_mca_enabled(self, arch_setup):
        arch, cfg, model, params, batch = arch_setup
        if cfg.family == "ssm":
            pytest.skip("MCA inapplicable to attention-free arch")
        from repro.core.policy import MCAConfig
        cfg2 = cfg.replace(mca=MCAConfig(enabled=True, alpha=0.4, block=16,
                                         sites=("v_proj",)))
        model2 = build_model(cfg2)
        loss, metrics = jax.jit(
            lambda p, b, k: model2.loss(p, b, k))(
                params, batch, jax.random.PRNGKey(2))
        assert np.isfinite(float(loss)), f"{arch}: MCA loss not finite"
        assert float(metrics["mca_flops"]) > 0
        assert float(metrics["mca_flops"]) <= float(
            metrics["mca_exact_flops"]) + 1e-6


class TestDecode:
    def test_prefill_then_decode(self, arch_setup):
        arch, cfg, model, params, batch = arch_setup
        if not cfg.causal:
            pytest.skip("encoder-only: no decode step (per assignment)")
        t_off = cfg.n_patch_tokens if cfg.family == "vlm" else 0
        max_len = S + 8 + t_off
        cache, hidden, _ = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))(params, batch)
        assert np.all(np.isfinite(
            np.asarray(hidden[:, -1], np.float32))), f"{arch} prefill"
        tok = batch["tokens"][:, -1:]
        logits, cache = jax.jit(model.decode)(
            params, tok, cache, jnp.asarray(S + t_off, jnp.int32))
        assert logits.shape == (B, 1, cfg.padded_vocab)
        valid = np.asarray(logits[..., :cfg.vocab_size], np.float32)
        assert np.all(np.isfinite(valid)), f"{arch}: decode logits"
        # pad-vocab region is masked out
        if cfg.padded_vocab > cfg.vocab_size:
            assert float(logits[..., cfg.vocab_size:].max()) < -1e29

    def test_decode_matches_forward(self, arch_setup):
        """Greedy next-token from (prefill[:-1] + decode(last)) == full fwd.

        Prefill consumes tokens 0..S-2 into the cache/state; decoding the
        final token at t=S-1 must reproduce the full-forward logits of the
        last position (state equivalence across the two inference paths).
        """
        arch, cfg, model, params, batch = arch_setup
        if cfg.mca.enabled:
            pytest.skip("stochastic")
        if not cfg.causal:
            pytest.skip("encoder-only: no decode step (per assignment)")
        t_off = cfg.n_patch_tokens if cfg.family == "vlm" else 0
        max_len = S + 8 + t_off
        pre_batch = dict(batch)
        pre_batch["tokens"] = batch["tokens"][:, :S - 1]
        cache, _, _ = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))(params, pre_batch)
        logits_d, _ = jax.jit(model.decode)(
            params, batch["tokens"][:, -1:], cache,
            jnp.asarray(S - 1 + t_off, jnp.int32))
        # forward path: hidden of last position
        hidden, _, _ = model.forward_hidden(params, batch)
        if cfg.family == "vlm":
            hidden = hidden[:, cfg.n_patch_tokens:]
        from repro.models.api import _logits
        logits_f = _logits(params, cfg, hidden[:, -1:])
        da = np.asarray(logits_d[..., :cfg.vocab_size], np.float32)
        fa = np.asarray(logits_f[..., :cfg.vocab_size], np.float32)
        np.testing.assert_allclose(da, fa, rtol=2e-3, atol=2e-3)
