"""Per-kernel allclose tests vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import amm
from repro.kernels import (attn_colmax, flash_attention, mca_matmul,
                           mca_matmul_ragged)
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- mca_matmul
@pytest.mark.parametrize("m,d,f,block,r", [
    (128, 512, 128, 128, 3),
    (256, 1024, 256, 128, 8),
    (128, 256, 384, 128, 1),
    (64, 256, 64, 64, 5),        # small blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mca_matmul_fixed_matches_ref(m, d, f, block, r, dtype):
    key = jax.random.PRNGKey(m + d + r)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (m, d), dtype=dtype)
    w = jax.random.normal(kw, (d, f), dtype=dtype)
    probs = amm.block_probs(w, block)
    idx, inv_rp = amm.draw_block_samples(ks, probs, r)
    out = mca_matmul(x, w, idx, inv_rp, block=block)
    ref = kref.ref_mca_matmul_fixed(x, w, idx, inv_rp, block)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_mca_matmul_fixed_matches_core_sampled_matmul():
    """Kernel == core estimator == unbiased AMM path used by the policy."""
    key = jax.random.PRNGKey(0)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (128, 512))
    w = jax.random.normal(kw, (512, 128))
    probs = amm.block_probs(w, 128)
    idx, inv_rp = amm.draw_block_samples(ks, probs, 4)
    out_kernel = mca_matmul(x, w, idx, inv_rp, block=128)
    out_core = amm.sampled_matmul(x, w, idx, inv_rp, block=128)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_core),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,d,f,block,block_m,rmax", [
    (256, 512, 128, 128, 128, 4),
    (512, 1024, 256, 128, 128, 8),
])
def test_mca_matmul_ragged_matches_ref(m, d, f, block, block_m, rmax):
    key = jax.random.PRNGKey(7)
    kx, kw, kr, ks = jax.random.split(key, 4)
    x = jax.random.normal(kx, (m, d))
    w = jax.random.normal(kw, (d, f))
    m_tiles = m // block_m
    r_tile = jax.random.randint(kr, (m_tiles,), 1, rmax + 1)
    probs = amm.block_probs(w, block)
    idx = jax.random.categorical(ks, jnp.log(probs), shape=(m_tiles, rmax))
    inv_rp = 1.0 / (r_tile[:, None] * probs[idx])
    out = mca_matmul_ragged(x, w, r_tile, idx, inv_rp, block=block,
                            block_m=block_m)
    ref = kref.ref_mca_matmul_ragged(x, w, np.asarray(r_tile),
                                     idx, inv_rp, block, block_m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------- flash_attention
@pytest.mark.parametrize("b,hq,hkv,sq,skv,dh", [
    (1, 2, 2, 128, 128, 64),       # MHA square
    (2, 4, 2, 128, 128, 64),       # GQA
    (1, 8, 1, 256, 256, 128),      # MQA
    (1, 2, 2, 128, 256, 64),       # cross / history (non-causal)
])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, sq, skv, dh, causal, dtype):
    if causal and sq != skv:
        pytest.skip("causal offset covered by square cases")
    key = jax.random.PRNGKey(b * 100 + sq)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, sq, dh), dtype=dtype)
    k = jax.random.normal(kk, (b, hkv, skv, dh), dtype=dtype)
    v = jax.random.normal(kv, (b, hkv, skv, dh), dtype=dtype)
    scale = 1.0 / np.sqrt(dh)
    out, lse = flash_attention(q, k, v, scale=scale, causal=causal,
                               block_q=64, block_k=64)
    ref_out, ref_lse = kref.ref_attention(q, k, v, scale=scale, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(lse), np.asarray(ref_lse),
                               rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------- attn_colmax
@pytest.mark.parametrize("b,hq,hkv,s,dh", [
    (1, 2, 2, 128, 64),
    (2, 4, 2, 256, 64),
])
@pytest.mark.parametrize("causal", [False, True])
def test_attn_colmax_matches_ref(b, hq, hkv, s, dh, causal):
    key = jax.random.PRNGKey(s)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, s, dh))
    k = jax.random.normal(kk, (b, hkv, s, dh))
    v = jax.random.normal(kv, (b, hkv, s, dh))
    scale = 1.0 / np.sqrt(dh)
    _, lse = flash_attention(q, k, v, scale=scale, causal=causal,
                             block_q=64, block_k=64)
    cm = attn_colmax(q, k, lse, scale=scale, causal=causal, block_q=64,
                     block_k=64, reduce_heads=False)
    ref = kref.ref_colmax(q, k, lse, scale=scale, causal=causal)
    np.testing.assert_allclose(np.asarray(cm), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_colmax_is_valid_probability_mass(s=128):
    """colmax entries are in (0, 1] and every column someone attends to
    strongly is ~1 under a diagonal-dominant score matrix."""
    q = jnp.eye(s, 64)[None, None] * 10
    k = jnp.eye(s, 64)[None, None] * 10
    v = jnp.ones((1, 1, s, 64))
    _, lse = flash_attention(q, k, v, scale=1.0, causal=False)
    cm = attn_colmax(q, k, lse, scale=1.0, causal=False)
    assert float(cm.min()) > 0.0
    assert float(cm.max()) <= 1.0 + 1e-5
    assert float(cm[0, :64].min()) > 0.5  # diagonal keys dominate


def test_colmax_feeds_schedule_end_to_end():
    """flash lse -> colmax -> Eq.9 schedule produces sane r values."""
    from repro.core import schedule
    key = jax.random.PRNGKey(3)
    b, h, s, dh, d = 2, 4, 128, 64, 512
    q, k, v = (jax.random.normal(kk, (b, h, s, dh))
               for kk in jax.random.split(key, 3))
    scale = 1.0 / np.sqrt(dh)
    _, lse = flash_attention(q, k, v, scale=scale, causal=True)
    cm = attn_colmax(q, k, lse, scale=scale, causal=True)   # [B, S]
    r = schedule.r_cols_from_attention(cm, s, alpha=0.4, d=d)
    assert r.shape == (b, s)
    assert bool(jnp.all((r >= 1.0) & (r <= d)))


def test_tiered_dispatch_kernel_path_matches_jnp():
    """use_kernel=True (Pallas interpret) == jnp path inside the tiered
    dispatch (Mode-C integration; same RNG -> identical sample sets)."""
    from repro.core import dispatch
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    n, d, f, block = 256, 512, 128, 128
    x = jax.random.normal(kx, (n, d))
    w = jax.random.normal(kw, (d, f))
    tier = jnp.asarray([0, 1, 2, 3] * (n // 4), jnp.int32)
    imp = jnp.linspace(0, 1, n)
    ladder = (1, 2, 4, 4)
    caps = (n, n, n, n)
    y_ref = dispatch.tiered_mca_matmul(key, x, w, tier, imp, ladder, caps,
                                       block=block, use_kernel=False)
    y_ker = dispatch.tiered_mca_matmul(key, x, w, tier, imp, ladder, caps,
                                       block=block, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


# -------------------------------------------------------- kv_slot_update
@pytest.mark.parametrize("b,s,trail", [
    (4, 16, (2, 64)),       # GQA-shaped [B,S,hkv,dh], f=128 -> kernel path
    (1, 8, (256,)),         # MLA latent [B,S,dl], kernel path
    (3, 12, (5, 7)),        # f=35: not lane-aligned -> scatter fallback
])
def test_kv_slot_update_per_row_write(b, s, trail):
    from repro.kernels import kv_slot_update
    key = jax.random.PRNGKey(b * 100 + s)
    kc, kn = jax.random.split(key)
    cache = jax.random.normal(kc, (b, s) + trail)
    new = jax.random.normal(kn, (b, 1) + trail)
    pos = jnp.asarray([(3 * i + 1) % s for i in range(b)], jnp.int32)
    out = kv_slot_update(cache, new, pos)
    ref = cache.at[jnp.arange(b), pos].set(new[:, 0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=0)


def test_kv_slot_update_dispatch_counters():
    """Lane-aligned feature dims take the Pallas kernel; others fall back
    to the XLA scatter — recorded at dispatch time in repro.obs."""
    from repro import obs
    from repro.kernels import kv_slot_update
    with obs.scoped() as reg:
        kv_slot_update(jnp.zeros((2, 4, 128)), jnp.ones((2, 1, 128)),
                       jnp.zeros(2, jnp.int32))
        kv_slot_update(jnp.zeros((2, 4, 5)), jnp.ones((2, 1, 5)),
                       jnp.zeros(2, jnp.int32))
        snap = reg.snapshot()
    c = snap["counters"]
    assert c["kernels.kv_slot_update.kernel_calls"] == 1
    assert c["kernels.kv_slot_update.fallback_calls"] == 1


# ------------------------------------------------------ device telemetry
class TestDeviceTelemetry:
    """Per-execution launch counts (repro.obs.devtel) — distinct from the
    dispatch-time kernel_calls/fallback_calls counters: a jitted K-step
    decode scan is ONE traced call site but K device launches.  Telemetry
    is a trace-time flag, so every test compiles fresh functions under
    ``devtel.enabled_scope()``."""

    def _deltas(self, fn):
        from repro.obs import devtel
        base = devtel.totals()
        jax.block_until_ready(fn())
        devtel.sync()
        return devtel.since(base)

    def test_kv_update_scan_counts_every_launch_kernel_path(self):
        from repro.kernels import kv_slot_update
        from repro.obs import devtel
        b, s, f, steps = 2, 16, 128, 5
        with devtel.enabled_scope():
            @jax.jit
            def burst(cache, new, pos):
                def body(c, i):
                    return kv_slot_update(c, new, pos + i), ()
                return jax.lax.scan(body, cache, jnp.arange(steps))[0]
            d = self._deltas(lambda: burst(jnp.zeros((b, s, f)),
                                           jnp.ones((b, 1, f)),
                                           jnp.zeros(b, jnp.int32)))
        assert d["kernels.kv_slot_update.device_launches"] == steps
        assert d["kernels.kv_slot_update.device_rows_written"] == steps * b

    def test_kv_update_scan_counts_every_launch_fallback_path(self):
        from repro.kernels import kv_slot_update
        from repro.obs import devtel
        b, s, f, steps = 3, 16, 96, 4          # f % 128 != 0 -> scatter
        with devtel.enabled_scope():
            @jax.jit
            def burst(cache, new, pos):
                def body(c, i):
                    return kv_slot_update(c, new, pos + i), ()
                return jax.lax.scan(body, cache, jnp.arange(steps))[0]
            d = self._deltas(lambda: burst(jnp.zeros((b, s, f)),
                                           jnp.ones((b, 1, f)),
                                           jnp.zeros(b, jnp.int32)))
        assert d["kernels.kv_slot_update.device_launches"] == steps
        assert d["kernels.kv_slot_update.device_rows_written"] == steps * b

    def test_mca_fixed_sampled_blocks_kernel_path(self):
        from repro.obs import devtel
        key = jax.random.PRNGKey(3)
        kx, kw, ks = jax.random.split(key, 3)
        x = jax.random.normal(kx, (256, 512))   # 2 row tiles of 128
        w = jax.random.normal(kw, (512, 128))
        probs = amm.block_probs(w, 128)
        idx, inv_rp = amm.draw_block_samples(ks, probs, 3)
        with devtel.enabled_scope():
            d = self._deltas(lambda: mca_matmul(x, w, idx, inv_rp,
                                                block=128))
        assert d["kernels.mca_matmul.device_launches"] == 1
        # kernel accumulates one count per (row tile, sample): 2 * 3
        assert d["kernels.mca_matmul.device_sampled_blocks"] == 6

    def test_mca_fixed_sampled_blocks_fallback_path(self):
        from repro.obs import devtel
        key = jax.random.PRNGKey(4)
        kx, kw, ks = jax.random.split(key, 3)
        x = jax.random.normal(kx, (200, 512))   # 200 % 128 != 0 -> ref
        w = jax.random.normal(kw, (512, 128))
        probs = amm.block_probs(w, 128)
        idx, inv_rp = amm.draw_block_samples(ks, probs, 3)
        with devtel.enabled_scope():
            d = self._deltas(lambda: mca_matmul(x, w, idx, inv_rp,
                                                block=128))
        assert d["kernels.mca_matmul.device_launches"] == 1
        # dense fallback has no row tiling: counts the sample list length
        assert d["kernels.mca_matmul.device_sampled_blocks"] == 3

    def test_mca_ragged_counts_accumulated_blocks_only(self):
        from repro.obs import devtel
        key = jax.random.PRNGKey(5)
        kx, kw, ks = jax.random.split(key, 3)
        m, d_, f, block, rmax = 256, 512, 128, 128, 4
        x = jax.random.normal(kx, (m, d_))
        w = jax.random.normal(kw, (d_, f))
        r_tile = jnp.asarray([1, 3], jnp.int32)  # 2 row tiles
        probs = amm.block_probs(w, block)
        idx = jax.random.categorical(ks, jnp.log(probs), shape=(2, rmax))
        inv_rp = 1.0 / (r_tile[:, None] * probs[idx])
        with devtel.enabled_scope():
            dl = self._deltas(lambda: mca_matmul_ragged(
                x, w, r_tile, idx, inv_rp, block=block, block_m=128))
        assert dl["kernels.mca_matmul_ragged.device_launches"] == 1
        # pl.when skips samples past r_tile[t]: only sum(r_tile) counted
        assert dl["kernels.mca_matmul_ragged.device_sampled_blocks"] == 4

    def test_flash_attention_counts_causal_tiles(self):
        from repro.obs import devtel
        key = jax.random.PRNGKey(6)
        kq, kk, kv = jax.random.split(key, 3)
        b, h, s, dh = 1, 2, 192, 64             # 3x3 tile grid at 64
        q = jax.random.normal(kq, (b, h, s, dh))
        k = jax.random.normal(kk, (b, h, s, dh))
        v = jax.random.normal(kv, (b, h, s, dh))
        with devtel.enabled_scope():
            d = self._deltas(lambda: flash_attention(
                q, k, v, scale=0.125, causal=True, block_q=64, block_k=64))
        assert d["kernels.flash_attention.device_launches"] == 1
        # causal skips strictly-upper tiles: b*h*6 of 9 computed
        assert d["kernels.flash_attention.device_tiles"] == b * h * 6

    def test_attn_colmax_counts_tiles_and_matches_flash(self):
        from repro.obs import devtel
        key = jax.random.PRNGKey(7)
        kq, kk, kv = jax.random.split(key, 3)
        b, h, s, dh = 1, 2, 192, 64
        q = jax.random.normal(kq, (b, h, s, dh))
        k = jax.random.normal(kk, (b, h, s, dh))
        v = jax.random.normal(kv, (b, h, s, dh))
        with devtel.enabled_scope():
            _, lse = flash_attention(q, k, v, scale=0.125, causal=True,
                                     block_q=64, block_k=64)
            d = self._deltas(lambda: attn_colmax(
                q, k, lse, scale=0.125, causal=True, block_q=64,
                block_k=64))
        assert d["kernels.attn_colmax.device_launches"] == 1
        assert d["kernels.attn_colmax.device_tiles"] == b * h * 6

    def test_disabled_emits_nothing(self):
        from repro.obs import devtel
        key = jax.random.PRNGKey(8)
        kx, kw, ks = jax.random.split(key, 3)
        x = jax.random.normal(kx, (128, 256))
        w = jax.random.normal(kw, (256, 128))
        probs = amm.block_probs(w, 128)
        idx, inv_rp = amm.draw_block_samples(ks, probs, 2)
        assert not devtel.enabled()
        d = self._deltas(lambda: mca_matmul(x, w, idx, inv_rp, block=128))
        assert not any(k.startswith("kernels.mca_matmul.device")
                       for k in d)

    def test_device_tier_hist_matches_stats_pytree(self):
        """The per-execution mca.device_tier_hist.t{i} totals must agree
        with the stats-pytree tier_hist the host reads once per step."""
        from repro.core.policy import MCAConfig, mca_project
        from repro.obs import devtel
        cfg = MCAConfig(enabled=True, alpha=0.4, block=16,
                        sites=("v_proj",))
        n, dm, f = 64, 64, 32
        key = jax.random.PRNGKey(9)
        kx, kw, ki = jax.random.split(key, 3)
        x = jax.random.normal(kx, (n, dm))
        w = jax.random.normal(kw, (dm, f))
        imp = jnp.abs(jax.random.normal(ki, (n,)))
        with devtel.enabled_scope():
            @jax.jit
            def run(key):
                _, stats = mca_project(key, x, w, imp, seq_len=n, cfg=cfg,
                                       site="v_proj")
                return stats["tier_hist"]
            base = devtel.totals()
            hist = np.asarray(run(jax.random.PRNGKey(10)))
            devtel.sync()
            deltas = devtel.since(base)
        assert int(hist.sum()) == n
        for i, hv in enumerate(hist):
            assert deltas.get(f"mca.device_tier_hist.t{i}", 0.0) == float(hv)

    def test_registry_snapshot_windows_device_totals(self):
        """Registries only see devtel activity since their creation, so
        scoped() collection stays isolated despite the global store."""
        from repro import obs
        from repro.obs import devtel
        b, s, f = 4, 8, 128
        with devtel.enabled_scope():
            @jax.jit
            def one(cache, new, pos):
                from repro.kernels import kv_slot_update
                return kv_slot_update(cache, new, pos)
            args = (jnp.zeros((b, s, f)), jnp.ones((b, 1, f)),
                    jnp.zeros(b, jnp.int32))
            jax.block_until_ready(one(*args))    # activity BEFORE scope
            devtel.sync()
            with obs.scoped() as reg:
                jax.block_until_ready(one(*args))
                devtel.sync()
                snap = reg.snapshot()
        c = snap["counters"]
        assert c["kernels.kv_slot_update.device_launches"] == 1
        assert c["kernels.kv_slot_update.device_rows_written"] == b
