"""Roofline report: read dryrun_results/*.json, emit the per-(arch x shape)
three-term table + analytic cross-checks.

Terms (per device, seconds):
  t_compute    = HLO_FLOPs / peak_bf16
  t_memory     = HLO_bytes / HBM_bw          (unfused upper bound — the CPU
                 cost model counts every elementwise intermediate; fused
                 TPU traffic is lower, see analytic_memory)
  t_collective = collective result bytes / ICI link bw

HLO numbers use the depth-extrapolation correction (scan bodies are
cost-counted once; see launch/dryrun.py).  MODEL_FLOPS = 6*N_active*D
(2*N*D for fwd-only kinds) and the useful fraction = MODEL/HLO flops.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.launch.mesh import HW


def load_results(out_dir: str = "dryrun_results") -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def analytic_memory_bytes(cell: dict, corrected: dict) -> float:
    """Fused-traffic estimate: params read per pass + 2x activation bytes
    per matmul boundary ~= model_flops / intensity. We approximate with
    bytes = max(arg bytes, flops / 100) — a 100-FLOP/byte fusion assumption
    consistent with bf16 transformer blocks at these widths."""
    return corrected["flops"] / 100.0


def table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | mesh | mca | fits | t_comp | t_mem(ub) | "
           "t_coll | bottleneck | MODEL/HLO | compile_s |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["cell"]["arch"],
                                         r["cell"]["shape"],
                                         r["cell"]["multi_pod"])):
        c = r["cell"]
        mesh = "2x16x16" if c["multi_pod"] else "16x16"
        if "error" in r:
            out.append(f"| {c['arch']} | {c['shape']} | {mesh} | "
                       f"{'on' if c['mca'] else 'off'} | FAIL | | | | | | |")
            continue
        temp = r.get("temp_size_in_bytes", 0)
        fits = "Y" if temp <= 16e9 else f"N({temp / 1e9:.0f}G)"
        corr = r.get("corrected", {})
        rt = dict(corr.get("roofline", r.get("roofline_raw", {})))
        for k in ("t_compute", "t_memory", "t_collective"):
            if k in rt:
                rt[k] = max(rt[k], 0.0)   # extrapolation-noise clamp
        uf = corr.get("useful_fraction", float("nan"))
        out.append(
            f"| {c['arch']} | {c['shape']} | {mesh} | "
            f"{'on' if c['mca'] else 'off'} | {fits} | "
            f"{rt.get('t_compute', 0):.3f} | {rt.get('t_memory', 0):.3f} | "
            f"{rt.get('t_collective', 0):.3f} | "
            f"{rt.get('bottleneck', '?')[2:]} | {uf:.2f} | "
            f"{r.get('compile_s', 0):.0f} |")
    return "\n".join(out)


def summary(rows: List[dict]) -> Dict:
    ok = [r for r in rows if "error" not in r]
    fail = [r for r in rows if "error" in r]
    fits = [r for r in ok if r.get("temp_size_in_bytes", 0) <= 16e9]
    return {"cells": len(rows), "compiled": len(ok), "failed": len(fail),
            "fits_hbm": len(fits)}


if __name__ == "__main__":
    rows = load_results()
    print(table(rows))
    print(summary(rows))
