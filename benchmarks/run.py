"""Benchmark entrypoint: one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's
headline number) and writes a schema-stable JSON report consumable by
``benchmarks.compare``:

    {"schema_version": 1, "profile": "smoke|fast|full",
     "kernels": [...], "tables": {"table1": [...], ...},
     "serve_throughput": {...},
     "fig1": {...}|null, "roofline_summary": {...}|null,
     "obs": <repro.obs registry snapshot>}

``--params-cache DIR`` caches trained classifier params on disk keyed by
a content hash of the training config, so repeat runs (CI) skip the
training loops entirely.

Profiles: ``full`` = paper-scale task counts/seeds; ``fast`` (default)
completes on CPU in minutes; ``smoke`` is the CI budget (~1-2 min) —
schema-identical, numbers undertrained/noisy by design."""
from __future__ import annotations

import argparse
import json
import time

from repro import obs

SCHEMA_VERSION = 1


def _csv(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _mean_reduction(table):
    red = [row["flops_reduction"] for r in table for row in r["rows"][1:]]
    return sum(red) / max(len(red), 1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=("smoke", "fast", "full"),
                    default="fast")
    ap.add_argument("--full", action="store_true",
                    help="legacy alias for --profile full")
    ap.add_argument("--json-out", default="bench_results.json")
    ap.add_argument("--params-cache", default=None, metavar="DIR",
                    help="cache trained table params here (content-hash "
                         "keyed); repeat runs skip training")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON span timeline "
                         "here (enables span tracing for the run)")
    args = ap.parse_args()
    profile = "full" if args.full else args.profile
    fast = profile != "full"
    smoke = profile == "smoke"

    if args.trace_out:
        obs.enable_tracing(True)
    reg = obs.Registry()
    tables = {}
    fig1 = None
    roofline_summary = None
    with obs.scoped(reg), obs.trace("benchmarks.run"):
        from . import kernel_bench
        with obs.span("kernel_bench", cat="bench", track="bench"):
            kb = kernel_bench.run(fast=fast)
        for r in kb:
            _csv(r["name"], r["us_per_call"],
                 r.get("flops_reduction", r.get("colmax_overhead", "")))

        from . import table1_bert, table2_distilbert, table3_longformer
        for name, mod in (("table1", table1_bert),
                          ("table2", table2_distilbert),
                          ("table3", table3_longformer)):
            t0 = time.time()
            with obs.span(name, cat="bench", track="bench"):
                tab = mod.run(fast=fast, smoke=smoke,
                              cache_dir=args.params_cache)
            wall = time.time() - t0
            tables[name] = tab
            reg.histogram(f"bench.{name}.wall_seconds").observe(wall)
            _csv(f"{name}_mca", wall * 1e6 / max(len(tab), 1),
                 f"mean_flops_reduction={_mean_reduction(tab):.2f}x")

        from . import serve_throughput as serve_mod
        t0 = time.time()
        with obs.span("serve_throughput", cat="bench", track="bench"):
            serve_tp = serve_mod.run(fast=fast, smoke=smoke)
        for row in serve_tp["rows"]:
            _csv(f"serve_{row['batcher']}", (time.time() - t0) * 1e6 / 2,
                 f"tokens_per_s={row['tokens_per_s']:.0f};"
                 f"prefill_ratio={row['prefill_flops_ratio']:.2f}x;"
                 f"parity={row['parity_ok']}")

        if not smoke:
            from . import fig1_tradeoff
            t0 = time.time()
            fig1 = fig1_tradeoff.run(fast=fast)
            knee = min((row for row in fig1["bert"]["rows"][1:]),
                       key=lambda r: abs(r["acc"]
                                         - fig1["bert"]["baseline_acc"]
                                         + 0.01))
            _csv("fig1_tradeoff", (time.time() - t0) * 1e6 / 8,
                 f"knee_alpha={knee['alpha']};"
                 f"knee_flops={knee['flops_reduction']:.2f}x")

        # roofline summary from the dry-run cache (if present)
        try:
            from . import roofline
            rows = roofline.load_results()
            if rows:
                roofline_summary = roofline.summary(rows)
                _csv("roofline_dryrun", 0.0,
                     f"cells={roofline_summary['cells']};"
                     f"compiled={roofline_summary['compiled']};"
                     f"fits={roofline_summary['fits_hbm']}")
        except Exception:                                 # noqa: BLE001
            pass

    out = {
        "schema_version": SCHEMA_VERSION,
        "profile": profile,
        "kernels": kb,
        "tables": tables,
        "serve_throughput": serve_tp,
        "fig1": fig1,
        "roofline_summary": roofline_summary,
        "obs": reg.snapshot(),
    }
    with open(args.json_out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"wrote {args.json_out} (profile={profile})")
    if args.trace_out:
        trace = obs.export_chrome_trace(args.trace_out, registry=reg)
        obs.enable_tracing(False)
        print(f"wrote {args.trace_out} "
              f"({len(trace['traceEvents'])} trace events)")


if __name__ == "__main__":
    main()
