"""Benchmark entrypoint: one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's
headline number).  ``--full`` runs paper-scale task counts/seeds; default
is the fast profile so `python -m benchmarks.run` completes on CPU."""
from __future__ import annotations

import argparse
import json
import time


def _csv(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json-out", default="bench_results.json")
    args = ap.parse_args()
    fast = not args.full
    results = {}

    from . import kernel_bench
    kb = kernel_bench.run(fast=fast)
    results["kernels"] = kb
    for r in kb:
        _csv(r["name"], r["us_per_call"],
             r.get("flops_reduction", r.get("colmax_overhead", "")))

    from . import table1_bert
    t0 = time.time()
    t1 = table1_bert.run(fast=fast)
    results["table1"] = t1
    us = (time.time() - t0) * 1e6
    red = [row["flops_reduction"] for r in t1 for row in r["rows"][1:]]
    acc_drop = [r["baseline_acc"] - r["rows"][1]["acc"] for r in t1]
    _csv("table1_mca_bert", us / max(len(red), 1),
         f"mean_flops_reduction={sum(red) / len(red):.2f}x"
         f";acc_drop_a0.2={sum(acc_drop) / len(acc_drop):.4f}")

    from . import table2_distilbert
    t0 = time.time()
    t2 = table2_distilbert.run(fast=fast)
    results["table2"] = t2
    us = (time.time() - t0) * 1e6
    red = [row["flops_reduction"] for r in t2 for row in r["rows"][1:]]
    _csv("table2_mca_distilbert", us / max(len(red), 1),
         f"mean_flops_reduction={sum(red) / len(red):.2f}x")

    from . import table3_longformer
    t0 = time.time()
    t3 = table3_longformer.run(fast=fast)
    results["table3"] = t3
    us = (time.time() - t0) * 1e6
    red = [row["flops_reduction"] for r in t3 for row in r["rows"][1:]]
    _csv("table3_mca_longformer", us / max(len(red), 1),
         f"mean_flops_reduction={sum(red) / len(red):.2f}x")

    from . import fig1_tradeoff
    t0 = time.time()
    f1 = fig1_tradeoff.run(fast=fast)
    results["fig1"] = f1
    us = (time.time() - t0) * 1e6
    knee = min((row for row in f1["bert"]["rows"][1:]),
               key=lambda r: abs(r["acc"] - f1["bert"]["baseline_acc"]
                                 + 0.01))
    _csv("fig1_tradeoff", us / 8,
         f"knee_alpha={knee['alpha']};knee_flops={knee['flops_reduction']:.2f}x")

    # roofline summary from the dry-run cache (if present)
    try:
        from . import roofline
        rows = roofline.load_results()
        if rows:
            s = roofline.summary(rows)
            _csv("roofline_dryrun", 0.0,
                 f"cells={s['cells']};compiled={s['compiled']};"
                 f"fits={s['fits_hbm']}")
            results["roofline_summary"] = s
    except Exception:                                     # noqa: BLE001
        pass

    with open(args.json_out, "w") as f:
        json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
