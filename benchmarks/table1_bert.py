"""Table 1: MCA-BERT on GLUE-like tasks — FLOPS reduction x accuracy vs alpha.

Mirrors the paper's Table 1 structure: rows = tasks, columns = alpha in
{0.2, 0.4, 0.6, 1.0} with accuracy (95% CI) and FLOPs-reduction factors.
"""
from __future__ import annotations

from . import glue_like as G

ALPHAS = (0.2, 0.4, 0.6, 1.0)

TASKS = (
    G.Task("syn-cola", seq_len=64, n_classes=2, seed=1),
    G.Task("syn-sst2", seq_len=128, n_classes=2, seed=2),
    G.Task("syn-mrpc", seq_len=128, n_classes=2, seed=3, noise=0.05),
    G.Task("syn-mnli", seq_len=192, n_classes=3, seed=4),
    G.Task("syn-rte", seq_len=96, n_classes=2, seed=5, noise=0.08),
)


def run(fast: bool = False, n_layers: int = 4, smoke: bool = False,
        cache_dir=None):
    # smoke: CI-budget profile (~tens of seconds) — schema-identical to
    # fast/full, numbers are noisy/undertrained by design
    if smoke:
        tasks, steps, n_seeds, n_eval = TASKS[:1], 60, 2, 128
        alphas = (0.2, 1.0)
        n_layers = min(n_layers, 2)
    else:
        tasks = TASKS[:2] if fast else TASKS
        steps = 120 if fast else 300
        n_seeds = 4 if fast else 8
        n_eval = 256 if fast else 512
        alphas = ALPHAS
    out = []
    for task in tasks:
        cfg = G.bert_config(n_layers=n_layers, seq_len=task.seq_len,
                            vocab=task.vocab)
        params = G.train_classifier(task, cfg, steps=steps, seed=task.seed,
                                    cache_dir=cache_dir)
        rows, base = G.mca_sweep(params, cfg, task, alphas,
                                 n_seeds=n_seeds, n_eval=n_eval)
        out.append({"task": task.name, "baseline_acc": base["acc"],
                    "rows": rows})
    return out


def format_table(results) -> str:
    lines = ["| task | base acc | " + " | ".join(
        f"a={a}: acc / FLOPSx" for a in ALPHAS) + " |",
        "|---|---|" + "---|" * len(ALPHAS)]
    for r in results:
        cells = []
        for row in r["rows"][1:]:
            cells.append(f"{row['acc']:.3f}±{row['ci95']:.3f} / "
                         f"{row['flops_reduction']:.2f}x")
        lines.append(f"| {r['task']} | {r['baseline_acc']:.3f} | "
                     + " | ".join(cells) + " |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
