"""Table 2: MCA-DistilBERT — same protocol on the 2x-compressed encoder,
showing MCA composes with model compression (paper Sec. 'Integration with
Compressed Transformers')."""
from __future__ import annotations

from . import table1_bert


def run(fast: bool = False, smoke: bool = False, cache_dir=None):
    # distil = half the layers of the table-1 encoder
    return table1_bert.run(fast=fast, n_layers=1 if smoke else 2,
                           smoke=smoke, cache_dir=cache_dir)


def format_table(results) -> str:
    return table1_bert.format_table(results)


if __name__ == "__main__":
    print(format_table(run()))
