"""Figure 1: accuracy vs FLOPs trade-off curves for MCA-BERT and
MCA-DistilBERT (fine alpha grid on one task)."""
from __future__ import annotations

from . import glue_like as G

ALPHA_GRID = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.8, 1.0)


def run(fast: bool = False):
    task = G.Task("syn-sst2", seq_len=128, n_classes=2, seed=2)
    steps = 120 if fast else 300
    out = {}
    for name, n_layers in (("bert", 4), ("distilbert", 2)):
        cfg = G.bert_config(n_layers=n_layers, seq_len=task.seq_len)
        params = G.train_classifier(task, cfg, steps=steps, seed=2)
        rows, base = G.mca_sweep(params, cfg, task, ALPHA_GRID,
                                 n_seeds=4, n_eval=256 if fast else 512)
        out[name] = {"baseline_acc": base["acc"], "rows": rows}
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
