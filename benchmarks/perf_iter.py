"""Perf-iteration tool: lower one dry-run cell with config/step overrides
and print the roofline delta vs the cached baseline.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch qwen3-32b \
        --shape train_4k [--mca] [--set n_micro=4] [--set banded_local=True]

Each invocation is one hypothesis->change->measure cycle; paste the output
into EXPERIMENTS.md §Perf.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import ast
import json

from repro.configs import SHAPES
from repro.launch.dryrun import (analyze, analyze_cell_extrapolated,
                                 lower_cell, roofline_terms)


def parse_set(items):
    out = {}
    for it in items or []:
        k, v = it.split("=", 1)
        try:
            out[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mca", action="store_true")
    ap.add_argument("--set", action="append", dest="sets",
                    help="cfg override, e.g. --set banded_local=True")
    ap.add_argument("--baseline", default="dryrun_results")
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--skip-extrapolation", action="store_true")
    args = ap.parse_args()

    overrides = parse_set(args.sets)
    print(f"== {args.arch} x {args.shape} mca={args.mca} "
          f"overrides={overrides}")

    lowered, compiled, meta = lower_cell(
        args.arch, args.shape, multi_pod=False, mca=args.mca,
        extra_overrides=overrides)
    res = analyze(compiled, meta, 256)
    print(f"compile {meta['compile_s']:.1f}s  "
          f"temp {res.get('temp_size_in_bytes', 0) / 1e9:.2f}GB")

    if not args.skip_extrapolation:
        corr = analyze_cell_extrapolated(args.arch, args.shape,
                                         mca=args.mca)
        # re-run extrapolation WITH the overrides
        from repro.launch import dryrun as dr
        from repro.configs import get_config
        base_cfg = get_config(args.arch)
        units_real = dr._real_units(base_cfg)
        results = {}
        for units in (1, 2):
            ov = dr._depth_overrides(base_cfg, units)
            ov.update(unroll_layers=True, unroll_inner=True)
            ov.update(overrides)
            _, comp, m = lower_cell(args.arch, args.shape, multi_pod=False,
                                    mca=args.mca, extra_overrides=ov)
            results[units] = analyze(comp, m, 256)

        def fit(key, sub=None):
            v1 = results[1][key] if sub is None else results[1][key][sub]
            v2 = results[2][key] if sub is None else results[2][key][sub]
            if isinstance(v1, dict):
                v1, v2 = v1["bytes"], v2["bytes"]
            return v1 + (v2 - v1) * (units_real - 1)

        cur = {"flops": fit("flops"),
               "bytes_accessed": fit("bytes_accessed"),
               "collectives": {"total_bytes": fit("collectives",
                                                  "total_bytes")}}
        rt = roofline_terms(cur)
        print(f"corrected: flops {cur['flops']:.3e} "
              f"bytes {cur['bytes_accessed']:.3e} "
              f"coll {cur['collectives']['total_bytes']:.3e}")
        print(f"terms: tc {rt['t_compute']:.3f} tm {rt['t_memory']:.3f} "
              f"tcoll {rt['t_collective']:.3f}  [{rt['bottleneck']}]")
        # per-kind collective census at units=2 (shape of traffic)
        print("collective census (units=2 unrolled):")
        for kind, st in results[2]["collectives"].items():
            if isinstance(st, dict) and st["count"]:
                print(f"  {kind:20s} x{st['count']:4d} "
                      f"{st['bytes'] / 1e9:7.2f} GB")

    # baseline comparison
    tag = f"{args.arch}__{args.shape}__sp__{'mca' if args.mca else 'base'}"
    path = os.path.join(args.baseline, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            base = json.load(f)
        bc = base.get("corrected", {})
        if bc and not args.skip_extrapolation:
            brt = bc.get("roofline", {})
            print(f"baseline terms: tc {brt.get('t_compute', 0):.3f} "
                  f"tm {brt.get('t_memory', 0):.3f} "
                  f"tcoll {brt.get('t_collective', 0):.3f}")
            print(f"baseline temp {base.get('temp_size_in_bytes', 0) / 1e9:.2f}GB")


if __name__ == "__main__":
    main()
