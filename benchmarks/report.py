"""Assemble EXPERIMENTS.md tables from dryrun_results/*.json.

    PYTHONPATH=src python -m benchmarks.report [--dir dryrun_results]

Replaces the <!-- DRYRUN_TABLE --> and <!-- ROOFLINE_TABLE --> markers in
EXPERIMENTS.md in place (idempotent: regenerates between marker lines).

With ``--bench bench_results.json`` it instead prints a latency
percentile table (p50/p95/p99, from the obs histogram summaries the
benchmark run recorded) to stdout.
"""
from __future__ import annotations

import argparse
import json
import math
import re

from . import roofline


def dryrun_table(rows) -> str:
    """Compile/fit proof table (both meshes)."""
    hdr = ("| arch | shape | mesh | compiled | temp GB | args GB | "
           "AG GB | AR GB | RS GB | A2A GB |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["cell"]["arch"],
                                         r["cell"]["shape"],
                                         r["cell"]["multi_pod"],
                                         r["cell"]["mca"])):
        c = r["cell"]
        if c["mca"]:
            continue
        mesh = "2x16x16" if c["multi_pod"] else "16x16"
        if "error" in r:
            out.append(f"| {c['arch']} | {c['shape']} | {mesh} | "
                       f"**FAIL** | | | | | | |")
            continue
        cl = r["collectives"]
        out.append(
            f"| {c['arch']} | {c['shape']} | {mesh} | "
            f"ok ({r.get('compile_s', 0):.0f}s) | "
            f"{r.get('temp_size_in_bytes', 0) / 1e9:.1f} | "
            f"{r.get('argument_size_in_bytes', 0) / 1e9:.1f} | "
            f"{cl['all-gather']['bytes'] / 1e9:.2f} | "
            f"{cl['all-reduce']['bytes'] / 1e9:.2f} | "
            f"{cl['reduce-scatter']['bytes'] / 1e9:.2f} | "
            f"{cl['all-to-all']['bytes'] / 1e9:.2f} |")
    return "\n".join(out)


def _ms(v: float) -> str:
    return "" if v is None or (isinstance(v, float) and math.isnan(v)) \
        else f"{v * 1e3:.2f}"


def latency_table(obs_snap: dict) -> str:
    """Percentile table over every ``*_seconds`` histogram in a snapshot.

    Columns are milliseconds; rows sorted by name.  Histograms that are
    not durations (no ``_seconds`` suffix) are skipped.
    """
    hdr = "| histogram | count | p50 ms | p95 ms | p99 ms | max ms |"
    out = [hdr, "|" + "---|" * 6]
    for name, h in sorted(obs_snap.get("histograms", {}).items()):
        if not name.endswith("_seconds"):
            continue
        out.append(f"| {name} | {int(h['count'])} | {_ms(h['p50'])} | "
                   f"{_ms(h['p95'])} | {_ms(h['p99'])} | {_ms(h['max'])} |")
    if len(out) == 2:
        out.append("| (no duration histograms recorded) | | | | | |")
    return "\n".join(out)


def splice(md_path: str, marker: str, content: str) -> None:
    with open(md_path) as f:
        text = f.read()
    block = f"<!-- {marker} -->\n{content}\n<!-- /{marker} -->"
    pat = re.compile(rf"<!-- {marker} -->.*?(<!-- /{marker} -->|$(?![\s\S]))",
                     re.S)
    if f"<!-- {marker} -->" in text:
        if f"<!-- /{marker} -->" in text:
            text = pat.sub(block, text)
        else:
            text = text.replace(f"<!-- {marker} -->", block)
    with open(md_path, "w") as f:
        f.write(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="dryrun_results")
    ap.add_argument("--md", default="EXPERIMENTS.md")
    ap.add_argument("--bench", default=None, metavar="JSON",
                    help="print a p50/p95/p99 latency table from this "
                         "benchmark JSON's obs snapshot and exit")
    args = ap.parse_args()
    if args.bench:
        with open(args.bench) as f:
            data = json.load(f)
        print(latency_table(data.get("obs", {})))
        return
    rows = roofline.load_results(args.dir)
    splice(args.md, "DRYRUN_TABLE", dryrun_table(rows))
    sp = [r for r in rows if not r["cell"]["multi_pod"]
          and not r["cell"]["mca"]]
    splice(args.md, "ROOFLINE_TABLE", roofline.table(sp))
    print(f"updated {args.md} from {len(rows)} cells; "
          f"summary: {roofline.summary(rows)}")


if __name__ == "__main__":
    main()
