"""Table 3: MCA-Longformer — sliding-window attention + MCA on longer
documents (paper Sec. 'Integration with Sparse Attention Patterns')."""
from __future__ import annotations

from . import glue_like as G

ALPHAS = (0.2, 0.4, 0.6, 1.0)

TASKS = (
    G.Task("syn-aapd", seq_len=192, n_classes=3, seed=11),
    G.Task("syn-hnd", seq_len=384, n_classes=2, seed=12),
    G.Task("syn-imdb", seq_len=256, n_classes=2, seed=13),
)


def run(fast: bool = False, window: int = 64, smoke: bool = False,
        cache_dir=None):
    if smoke:
        tasks, steps, n_seeds, n_eval = TASKS[:1], 60, 2, 128
        alphas, n_layers = (0.2, 1.0), 2
    else:
        tasks = TASKS[:1] if fast else TASKS
        steps = 120 if fast else 300
        n_seeds = 4 if fast else 8
        n_eval = 256 if fast else 512
        alphas, n_layers = ALPHAS, 4
    out = []
    for task in tasks:
        cfg = G.bert_config(n_layers=n_layers, window=window,
                            seq_len=task.seq_len, vocab=task.vocab)
        params = G.train_classifier(task, cfg, steps=steps, seed=task.seed,
                                    cache_dir=cache_dir)
        rows, base = G.mca_sweep(params, cfg, task, alphas,
                                 n_seeds=n_seeds, n_eval=n_eval)
        out.append({"task": task.name, "baseline_acc": base["acc"],
                    "window": window, "rows": rows})
    return out


def format_table(results) -> str:
    from .table1_bert import format_table as ft
    return ft(results)


if __name__ == "__main__":
    print(format_table(run()))
