"""Kernel microbenchmarks: wall time per call (CPU; interpret-mode numbers
are structural only — TPU is the target) + analytic FLOPs-reduction derived
from the MCA sampling schedule."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import amm
from repro.models import attention as attn


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6   # us


def bench_sampled_matmul(m=256, d=1024, f=256, r=2, block=128):
    key = jax.random.PRNGKey(0)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (m, d))
    w = jax.random.normal(kw, (d, f))
    probs = amm.block_probs(w, block)
    idx, inv = amm.draw_block_samples(ks, probs, r)

    dense = jax.jit(lambda x, w: x @ w)
    sampled = jax.jit(lambda x, w: amm.sampled_matmul(x, w, idx, inv, block))
    t_dense = _time(dense, x, w)
    t_samp = _time(sampled, x, w)
    k = d // block
    return {
        "name": "mca_sampled_matmul",
        "us_per_call": t_samp,
        "us_dense": t_dense,
        "flops_reduction": k / r,
        "speedup_wallclock_cpu": t_dense / t_samp,
    }


def bench_chunked_attention(b=2, s=512, h=4, dh=64, chunk=128):
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, h, 1, dh))
    k = jax.random.normal(kk, (b, s, h, dh))
    v = jax.random.normal(kv, (b, s, h, dh))
    scale = dh ** -0.5

    onepass = jax.jit(lambda q, k, v: attn.onepass_attention(
        q, k, v, scale=scale, causal=True, window=0, chunk=chunk)[0])
    t = _time(onepass, q, k, v)

    def three_pass(q, k, v):
        m, lse = attn.chunked_lse(q, k, scale=scale, causal=True, window=0,
                                  chunk=chunk)
        cm = attn.chunked_colmax(q, k, lse, scale=scale, causal=True,
                                 window=0, chunk=chunk)
        out = attn.chunked_av(q, k, v, lse, scale=scale, causal=True,
                              window=0, chunk=chunk)
        return out, cm
    t3 = _time(jax.jit(three_pass), q, k, v)
    return {
        "name": "chunked_attention",
        "us_per_call": t,
        "us_mca_3pass": t3,
        "colmax_overhead": t3 / t,
    }


def run(fast: bool = False):
    return [bench_sampled_matmul(), bench_chunked_attention()]


if __name__ == "__main__":
    for r in run():
        print(r)
