"""Synthetic GLUE-like classification harness for the MCA tables.

No GLUE data ships offline, so each "task" is a seeded synthetic
classification problem with planted k-gram motifs: class c plants motifs
from its own motif set into a background token stream; recovering the
label requires attending to the motif positions — which gives trained
models the concentrated attention profiles MCA exploits, just like real
GLUE encoders.  Accuracy deltas under MCA are therefore *real* model
accuracy deltas, and FLOPs accounting follows the paper (attention
encoding AXW only).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.core.policy import MCAConfig
from repro.models import build_model, reduced
from repro.models import stack as stack_mod
from repro.models.common import (dense_init, embed_tokens, init_embedding,
                                 init_norm, apply_norm, sinusoidal_pos_emb)
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class Task:
    name: str
    seq_len: int = 128
    n_classes: int = 2
    vocab: int = 512
    n_motifs: int = 8       # motifs per class
    motif_len: int = 3
    noise: float = 0.02
    seed: int = 0


def gen_batch(task: Task, rng: np.random.Generator, batch: int
              ) -> Dict[str, np.ndarray]:
    mot_rng = np.random.default_rng(task.seed + 999)
    motifs = mot_rng.integers(
        2, task.vocab, size=(task.n_classes, task.n_motifs, task.motif_len))
    labels = rng.integers(0, task.n_classes, size=batch)
    toks = rng.integers(2, task.vocab, size=(batch, task.seq_len))
    toks[:, 0] = 1                                    # CLS
    for i in range(batch):
        n_plant = rng.integers(2, 5)
        for _ in range(n_plant):
            m = motifs[labels[i], rng.integers(0, task.n_motifs)]
            p = rng.integers(1, task.seq_len - task.motif_len)
            toks[i, p:p + task.motif_len] = m
    flip = rng.random(batch) < task.noise
    labels = np.where(flip, rng.integers(0, task.n_classes, batch), labels)
    return {"tokens": toks.astype(np.int32),
            "label": labels.astype(np.int32)}


# ------------------------------------------------------------ classifier
def bert_config(n_layers=4, window=0, mca: MCAConfig = MCAConfig(),
                seq_len=128, vocab=512):
    cfg = get_config("bert-base")
    return reduced(cfg, n_layers=n_layers, vocab_size=vocab,
                   d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
                   d_ff=256, window=window, mca=mca,
                   unroll_layers=True, remat=False, attn_chunk=64)


def init_classifier(key, cfg, n_classes: int):
    ks = jax.random.split(key, 3)
    return {
        "embed": init_embedding(ks[0], cfg),
        "layers": stack_mod.init_stack(ks[1], cfg, cfg.n_layers, "attn_ffn"),
        "final_norm": init_norm(cfg),
        "head": dense_init(ks[2], cfg.d_model, n_classes, jnp.float32),
    }


def classifier_logits(params, cfg, tokens, mca_key=None):
    x = embed_tokens(params["embed"], tokens)
    x = x + sinusoidal_pos_emb(x.shape[1], cfg.d_model, x.dtype)[None]
    pos = jnp.arange(x.shape[1])[None]
    x, _, stats = stack_mod.stack_forward(
        params["layers"], cfg, x, pos=pos, mca_key=mca_key,
        kind="attn_ffn", causal=False, window=cfg.window)
    x = apply_norm(params["final_norm"], cfg, x)
    cls = x[:, 0]                                     # CLS pooling
    return cls @ params["head"], stats


def classifier_loss(params, cfg, batch, mca_key=None):
    logits, stats = classifier_logits(params, cfg, batch["tokens"], mca_key)
    onehot = jax.nn.one_hot(batch["label"], logits.shape[-1])
    loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, axis=-1))
    return loss, stats


def _params_cache_key(task: Task, cfg, steps, batch, lr, seed) -> str:
    """Content hash of everything that determines the trained params.

    ``repr(cfg)`` covers every model hyperparameter (dataclass repr is
    field-complete); training is single-host deterministic given the
    seed, so equal keys mean bit-equal training runs.
    """
    spec = repr((task, cfg.replace(mca=MCAConfig(enabled=False)),
                 steps, batch, lr, seed))
    return hashlib.sha256(spec.encode()).hexdigest()[:24]


def train_classifier(task: Task, cfg, *, steps=300, batch=32, lr=3e-3,
                     seed=0, cache_dir=None):
    """Train with exact attention (models are trained normally; MCA is a
    drop-in inference replacement, per the paper).

    ``cache_dir`` caches the trained params on disk keyed by a content
    hash of (task, cfg, steps, batch, lr, seed) — the tables re-train
    identical classifiers across runs, so CI reuses them instead of
    burning its budget on repeat training.
    """
    cfg_train = cfg.replace(mca=MCAConfig(enabled=False))
    path = None
    if cache_dir is not None:
        key = _params_cache_key(task, cfg, steps, batch, lr, seed)
        path = os.path.join(cache_dir, f"params-{key}.pkl")
        if os.path.exists(path):
            obs.get_registry().counter("bench.params_cache.hits").inc()
            with open(path, "rb") as f:
                return pickle.load(f)
        obs.get_registry().counter("bench.params_cache.misses").inc()
    params = init_classifier(jax.random.PRNGKey(seed), cfg_train,
                             task.n_classes)
    opt_cfg = adamw.AdamWConfig(lr=lr, weight_decay=0.01, clip_norm=1.0)
    opt = adamw.init_state(params)

    @jax.jit
    def step(params, opt, batch_in):
        (loss, _), grads = jax.value_and_grad(
            lambda p: classifier_loss(p, cfg_train, batch_in),
            has_aux=True)(params)
        params, opt, _ = adamw.apply_updates(opt_cfg, params, grads, opt)
        return params, opt, loss

    rng = np.random.default_rng(seed + 1)
    for i in range(steps):
        b = gen_batch(task, rng, batch)
        params, opt, loss = step(params, opt,
                                 jax.tree.map(jnp.asarray, b))
    if path is not None:
        os.makedirs(cache_dir, exist_ok=True)
        tmp = path + f".tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            pickle.dump(jax.device_get(params), f)
        os.replace(tmp, path)              # atomic: no torn cache entries
    return params


def evaluate(params, cfg, task: Task, *, mca_key=None, n_eval=512,
             eval_seed=10_000):
    rng = np.random.default_rng(eval_seed)
    b = gen_batch(task, rng, n_eval)

    @jax.jit
    def fwd(params, tokens, key):
        return classifier_logits(params, cfg, tokens, key)

    logits, stats = fwd(params, jnp.asarray(b["tokens"]), mca_key)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(b["label"])))
    exact = float(stats["exact_flops"])
    mca = float(stats["mca_flops"])
    hist = np.asarray(stats["tier_hist"], np.float64)
    total = hist.sum()
    return {"acc": acc, "flops_reduction": exact / max(mca, 1.0),
            "exact_flops": exact, "mca_flops": mca,
            "tier_hist": (hist / max(total, 1.0)).tolist()}


def mca_sweep(params, cfg, task: Task, alphas, *, n_seeds=8, mode="per_token",
              sites=("v_proj",), n_eval=512):
    """Paper-style sweep: accuracy (mean +/- 95% CI over RNG seeds) and
    FLOPs reduction per alpha."""
    rows = []
    base = evaluate(params, cfg, task, mca_key=None, n_eval=n_eval)
    rows.append({"alpha": 0.0, "acc": base["acc"], "ci95": 0.0,
                 "acc_delta": 0.0, "flops_reduction": 1.0,
                 "tier_hist": base["tier_hist"]})
    for alpha in alphas:
        cfg_a = cfg.replace(mca=MCAConfig(
            enabled=True, alpha=alpha, block=16, mode=mode, sites=sites))
        accs, reds, hists = [], [], []
        for s in range(n_seeds):
            r = evaluate(params, cfg_a, task,
                         mca_key=jax.random.PRNGKey(1000 + s),
                         n_eval=n_eval)
            accs.append(r["acc"])
            reds.append(r["flops_reduction"])
            hists.append(r["tier_hist"])
        accs = np.asarray(accs)
        ci = (1.96 * accs.std(ddof=1) / np.sqrt(len(accs))
              if len(accs) > 1 else 0.0)
        rows.append({
            "alpha": alpha,
            "acc": float(accs.mean()),
            "ci95": float(ci),
            "acc_delta": float(accs.mean() - base["acc"]),
            "flops_reduction": float(np.mean(reds)),
            "tier_hist": np.mean(np.asarray(hists), axis=0).tolist(),
        })
    return rows, base
