"""Serving throughput: per-slot continuous batching vs the wave batcher.

A ragged Zipf-ish workload (mostly short prompts, a heavy tail of long
ones — the regime continuous batching exists for) is served twice on the
same engine shape:

* ``wave`` — ``ContinuousBatcher``: every wave prefills at the wave's max
  prompt length across all slots and decodes to the wave's max ``max_new``.
* ``per_slot`` — ``SlotBatcher``: each request prefills once (batch=1,
  pow-2 bucket) into its own slot; nothing is re-encoded.

Each row reports end-to-end ``tokens_per_s``, the prefill token count
(``prefill_tokens`` — proportional to prefill FLOPs at fixed model shape),
``prefill_flops_ratio`` (wave tokens / this row's tokens; the acceptance
bar is >= 1.5x for per_slot), insertion counters, and ``parity_ok``:
every request's tokens must be identical to a solo batch=1 generation
(MCA off — capacity routing couples batch rows by design, so token
identity is only defined for the exact path).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.models import build_model, reduced
from repro.serve import ContinuousBatcher, Engine, Request, SlotBatcher

BATCH = 4
MAX_LEN = 96
N_REQ = 12
SEED = 3          # Zipf draw with a long prompt per wave-of-4 (see module
                  # docstring; ratio is workload-dependent by design)


def _workload(vocab):
    rng = np.random.default_rng(SEED)
    lens = np.minimum(3 + rng.zipf(1.5, N_REQ), 48)
    max_news = 4 + rng.integers(0, 7, N_REQ)
    prompts = [rng.integers(1, vocab, size=int(n)).astype(np.int32)
               for n in lens]
    return prompts, [int(m) for m in max_news]


def _serve(batcher_cls, eng, prompts, max_news, **kw):
    reg = obs.Registry()
    with obs.scoped(reg):
        b = batcher_cls(eng, **kw)
        for i, (p, m) in enumerate(zip(prompts, max_news)):
            assert b.submit(Request(uid=i, prompt=p, max_new=m)) == "queued"
        t0 = time.perf_counter()
        out = b.run()
        wall = time.perf_counter() - t0
    snap = reg.snapshot()
    assert all(b.status[i] == "ok" for i in range(len(prompts))), b.status
    if obs.tracing_enabled():
        # the serve spans landed in this scoped registry; copy them out to
        # the ambient one so run.py's --trace-out export sees the chains
        ambient = obs.get_registry()
        for s in reg.spans():
            ambient.add_span(s)
    return out, wall, snap["counters"], snap["gauges"]


def run(fast: bool = True, smoke: bool = False):
    del fast, smoke          # one scale: the workload IS the benchmark
    cfg = reduced(get_config("starcoder2-3b"), n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts, max_news = _workload(cfg.vocab_size)
    n_tokens = sum(max_news)

    # solo reference: each request alone on a batch=1 engine (ground truth
    # for parity — continuous batching must not change anyone's tokens)
    solo = Engine(model, params, batch_size=1, max_len=MAX_LEN)
    ref = {i: solo.generate(p[None, :], m, mca=False)[0].tolist()
           for i, (p, m) in enumerate(zip(prompts, max_news))}

    rows = []
    walls = {}
    for name, cls, kw in (("wave", ContinuousBatcher, {}),
                          ("per_slot", SlotBatcher, {"check_every": 8})):
        eng = Engine(model, params, batch_size=BATCH, max_len=MAX_LEN)
        # warmup pass populates the engine's jit caches (per-bucket
        # insertion, burst) so tokens_per_s is steady-state, not compile
        _serve(cls, eng, prompts, max_news, **kw)
        out, wall, c, g = _serve(cls, eng, prompts, max_news, **kw)
        walls[name] = wall
        rows.append({
            "batcher": name,
            "tokens_per_s": n_tokens / wall,
            "prefill_tokens": c.get("serve.prefill_tokens", 0.0),
            "prefill_tokens_saved": c.get("serve.prefill_tokens_saved",
                                          0.0),
            "insertions": c.get("serve.insertions", 0.0),
            "slot_idle_steps": c.get("serve.slot_idle_steps", 0.0),
            "slot_utilization": g.get("serve.slot_utilization", 0.0),
            "parity_ok": all(out.get(i) == ref[i] for i in ref),
        })
    wave_tokens = rows[0]["prefill_tokens"]
    for r in rows:
        r["prefill_flops_ratio"] = (wave_tokens
                                    / max(r["prefill_tokens"], 1.0))
    return {"n_requests": N_REQ, "n_tokens": n_tokens, "batch": BATCH,
            "max_len": MAX_LEN, "rows": rows}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
