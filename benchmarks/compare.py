"""Regression gate: diff two ``benchmarks.run`` JSON reports.

    python -m benchmarks.compare BASELINE.json CANDIDATE.json \
        [--report-only] [--threshold accuracy_abs=0.1 ...]

Per-metric thresholds (all overridable on the CLI):

* ``timing_ratio``       — kernel us_per_call may grow at most this factor
                           (wall-clock on shared CI is noisy; 2.5x default).
* ``flops_reduction_rel``— relative drift allowed in each row's FLOPs
                           reduction (deterministic given seeds; drift means
                           the sampling schedule changed).
* ``accuracy_abs``       — absolute accuracy drift allowed per row.
* ``tier_hist_l1``       — L1 distance allowed between normalized tier
                           occupancy histograms.
* ``tokens_per_s_rel``   — serve_throughput tokens/s may drop at most
                           this fraction below baseline per batcher row
                           (prefill-FLOPs ratio and token parity are
                           hard-gated, not thresholded).

Exit status: 0 when clean (or ``--report-only``), 1 when any regression
is found, 2 on malformed/incomparable inputs.  Comparing a report against
itself always exits 0.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

DEFAULT_THRESHOLDS: Dict[str, float] = {
    "timing_ratio": 2.5,
    "flops_reduction_rel": 0.25,
    "accuracy_abs": 0.05,
    "tier_hist_l1": 0.35,
    "tokens_per_s_rel": 0.10,
}


def _load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "traceEvents" in data:
        # --trace-out Chrome traces sit next to bench JSONs in CI
        # artifacts; they are timelines, not reports, and never gate.
        raise ValueError(f"{path} is a Chrome trace, not a benchmarks.run "
                         "report — trace files are not compared")
    return data


def compare(base: dict, cand: dict,
            thresholds: Dict[str, float] = None) -> List[str]:
    """Returns a list of human-readable regression strings (empty = clean)."""
    th = dict(DEFAULT_THRESHOLDS)
    if thresholds:
        th.update(thresholds)
    problems: List[str] = []

    if base.get("schema_version") != cand.get("schema_version"):
        raise ValueError(
            f"schema_version mismatch: {base.get('schema_version')} vs "
            f"{cand.get('schema_version')}")
    if base.get("profile") != cand.get("profile"):
        # comparable only within a profile — budgets change the numbers
        raise ValueError(f"profile mismatch: {base.get('profile')} vs "
                         f"{cand.get('profile')}")

    # ---- kernels: timing may not blow up
    base_k = {k["name"]: k for k in base.get("kernels", [])}
    for k in cand.get("kernels", []):
        b = base_k.get(k["name"])
        if b is None:
            continue                       # new kernel: not a regression
        if b["us_per_call"] > 0:
            ratio = k["us_per_call"] / b["us_per_call"]
            if ratio > th["timing_ratio"]:
                problems.append(
                    f"kernel {k['name']}: {k['us_per_call']:.1f}us vs "
                    f"baseline {b['us_per_call']:.1f}us "
                    f"({ratio:.2f}x > {th['timing_ratio']}x)")
    missing = set(base_k) - {k["name"] for k in cand.get("kernels", [])}
    for name in sorted(missing):
        problems.append(f"kernel {name}: present in baseline, missing in "
                        "candidate")

    # ---- tables: per-task, per-alpha rows
    for tname, btab in (base.get("tables") or {}).items():
        ctab = (cand.get("tables") or {}).get(tname)
        if ctab is None:
            problems.append(f"{tname}: missing in candidate")
            continue
        cmap = {t["task"]: t for t in ctab}
        for bt in btab:
            ct = cmap.get(bt["task"])
            if ct is None:
                problems.append(f"{tname}/{bt['task']}: missing in candidate")
                continue
            crows = {round(r["alpha"], 6): r for r in ct["rows"]}
            for br in bt["rows"]:
                cr = crows.get(round(br["alpha"], 6))
                loc = f"{tname}/{bt['task']}/alpha={br['alpha']}"
                if cr is None:
                    problems.append(f"{loc}: row missing in candidate")
                    continue
                d_acc = abs(cr["acc"] - br["acc"])
                if d_acc > th["accuracy_abs"]:
                    problems.append(
                        f"{loc}: acc {cr['acc']:.4f} vs {br['acc']:.4f} "
                        f"(|delta|={d_acc:.4f} > {th['accuracy_abs']})")
                if br["flops_reduction"] > 0:
                    rel = abs(cr["flops_reduction"] - br["flops_reduction"]
                              ) / br["flops_reduction"]
                    if rel > th["flops_reduction_rel"]:
                        problems.append(
                            f"{loc}: flops_reduction "
                            f"{cr['flops_reduction']:.3f} vs "
                            f"{br['flops_reduction']:.3f} "
                            f"(rel={rel:.3f} > {th['flops_reduction_rel']})")
                bh, ch = br.get("tier_hist"), cr.get("tier_hist")
                if bh and ch and len(bh) == len(ch):
                    l1 = sum(abs(a - b) for a, b in zip(bh, ch))
                    if l1 > th["tier_hist_l1"]:
                        problems.append(
                            f"{loc}: tier_hist L1 drift {l1:.3f} > "
                            f"{th['tier_hist_l1']}")

    # ---- serving throughput: tokens/s floor + hard invariants
    bst, cst = base.get("serve_throughput"), cand.get("serve_throughput")
    if bst and cst is None:
        problems.append("serve_throughput: missing in candidate")
    elif bst and cst:
        cmap = {r["batcher"]: r for r in cst.get("rows", [])}
        for br in bst.get("rows", []):
            cr = cmap.get(br["batcher"])
            loc = f"serve_throughput/{br['batcher']}"
            if cr is None:
                problems.append(f"{loc}: row missing in candidate")
                continue
            floor = br["tokens_per_s"] * (1.0 - th["tokens_per_s_rel"])
            if cr["tokens_per_s"] < floor:
                problems.append(
                    f"{loc}: tokens_per_s {cr['tokens_per_s']:.0f} vs "
                    f"baseline {br['tokens_per_s']:.0f} (> "
                    f"{th['tokens_per_s_rel']:.0%} regression)")
            if not cr.get("parity_ok", False):
                problems.append(f"{loc}: parity_ok is false — batched "
                                "tokens diverge from solo generation")
            # the tentpole's reason to exist: per-slot must keep beating
            # the wave batcher on prefill FLOPs
            if (br["batcher"] == "per_slot"
                    and cr["prefill_flops_ratio"]
                    < br["prefill_flops_ratio"] - 1e-6):
                problems.append(
                    f"{loc}: prefill_flops_ratio "
                    f"{cr['prefill_flops_ratio']:.2f} fell below baseline "
                    f"{br['prefill_flops_ratio']:.2f}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--report-only", action="store_true",
                    help="print regressions but always exit 0")
    ap.add_argument("--threshold", action="append", default=[],
                    metavar="NAME=VALUE",
                    help=f"override a threshold; known: "
                         f"{', '.join(DEFAULT_THRESHOLDS)}")
    args = ap.parse_args(argv)

    overrides = {}
    for spec in args.threshold:
        name, _, val = spec.partition("=")
        if name not in DEFAULT_THRESHOLDS or not val:
            print(f"unknown threshold {spec!r}", file=sys.stderr)
            return 2
        overrides[name] = float(val)

    try:
        problems = compare(_load(args.baseline), _load(args.candidate),
                           overrides)
    except (ValueError, KeyError, OSError, json.JSONDecodeError) as e:
        print(f"compare failed: {e}", file=sys.stderr)
        return 2

    if problems:
        print(f"{len(problems)} regression(s) vs {args.baseline}:")
        for p in problems:
            print(f"  REGRESSION {p}")
        return 0 if args.report_only else 1
    print(f"clean: {args.candidate} within thresholds of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
