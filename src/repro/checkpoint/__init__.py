from .checkpoint import (AsyncCheckpointer, CheckpointCorruptError,
                         CheckpointError, StructureMismatchError,
                         cleanup_stale_tmp, latest_step, restore,
                         restore_latest_valid, save, valid_steps)
