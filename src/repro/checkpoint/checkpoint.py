"""Atomic, async, resharding checkpoints.

Layout:  <dir>/step_<N>/   arrays.npz + manifest.json   (tmp-dir + rename
for atomicity).  Restore accepts a *different* mesh/shardings than the one
that saved — elastic restart (N hosts -> M hosts) is just restore with the
new shardings; leaves are device_put with the target NamedSharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bf16/f8 natively: store as a same-width uint view
# and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _to_storable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, logical: str):
    if logical in _EXOTIC:
        return arr.view(getattr(ml_dtypes, logical))
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the final directory."""
    leaves, paths, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays, dtypes = {}, []
    for i, leaf in enumerate(leaves):
        arr, logical = _to_storable(np.asarray(leaf))
        arrays[f"a{i}"] = arr
        dtypes.append(logical)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": dtypes,
        "shapes": [list(a.shape) for a in arrays.values()],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, *, shardings: Any = None):
    """Restore into the structure of ``like`` (tree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of
    NamedShardings for the *current* mesh (elastic reshard-on-restore)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves, paths, treedef = _flatten(like)
    assert paths == manifest["paths"], "checkpoint/model structure mismatch"
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(leaves))
    out = []
    for i, (leaf, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = _from_storable(data[f"a{i}"], manifest["dtypes"][i])
        expect = tuple(leaf.shape)
        assert arr.shape == expect, (paths[i], arr.shape, expect)
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """One-deep async write queue: snapshot to host, write on a thread.
    ``wait()`` blocks until the in-flight write lands (call before exit)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._thread = threading.Thread(
            target=save, args=(self.dir, step, host_tree),
            kwargs={"keep": self.keep}, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
