"""Atomic, async, resharding, integrity-checked checkpoints.

Layout:  <dir>/step_<N>/   arrays.npz + manifest.json   (tmp-dir + rename
for atomicity).  Restore accepts a *different* mesh/shardings than the one
that saved — elastic restart (N hosts -> M hosts) is just restore with the
new shardings; leaves are device_put with the target NamedSharding.

Integrity: the manifest records a CRC32 per stored array; ``restore``
verifies them and raises :class:`CheckpointCorruptError` naming the first
bad array.  ``restore_latest_valid`` walks steps newest-first, skipping
corrupt / torn checkpoints (counted as ``resilience.ckpt.corrupt_skipped``)
and structure-mismatched ones — e.g. a stale checkpoint from an older
model config sharing the dir (``resilience.ckpt.structure_skipped``) — so
a crashed-mid-write, bit-flipped, or incompatible step never bricks a
restart.  ``cleanup_stale_tmp`` removes ``step_*.tmp`` leftovers from a
crash between write and rename.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import zlib
from typing import Any, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro import obs, resilience

log = logging.getLogger("repro.checkpoint")

# numpy can't serialize bf16/f8 natively: store as a same-width uint view
# and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


class CheckpointError(RuntimeError):
    """Base class for checkpoint integrity / structure failures."""


class CheckpointCorruptError(CheckpointError):
    """A stored array failed its checksum or is missing/unreadable."""


class StructureMismatchError(CheckpointError):
    """The checkpoint's tree structure does not match the restore target."""


def _to_storable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, logical: str):
    if logical in _EXOTIC:
        return arr.view(getattr(ml_dtypes, logical))
    return arr


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the final directory."""
    leaves, paths, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays, dtypes, checksums = {}, [], []
    for i, leaf in enumerate(leaves):
        arr, logical = _to_storable(np.asarray(leaf))
        arrays[f"a{i}"] = arr
        dtypes.append(logical)
        checksums.append(_crc(arr))
    resilience.inject("ckpt.write")
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": dtypes,
        "shapes": [list(a.shape) for a in arrays.values()],
        "checksums": checksums,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _read_manifest(step_dir: str) -> Optional[dict]:
    """Manifest dict, or None if missing/unreadable (torn checkpoint)."""
    try:
        with open(os.path.join(step_dir, "manifest.json")) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def cleanup_stale_tmp(ckpt_dir: str) -> int:
    """Remove ``step_*.tmp`` leftovers from a crash mid-save. Returns the
    number of directories removed (also counted as
    ``resilience.ckpt.stale_tmp_removed``)."""
    if not os.path.isdir(ckpt_dir):
        return 0
    n = 0
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
            n += 1
    if n:
        obs.get_registry().counter(
            "resilience.ckpt.stale_tmp_removed").inc(n)
    return n


def valid_steps(ckpt_dir: str) -> List[int]:
    """Ascending step numbers whose directory has a readable manifest.
    Dirs with a missing/unreadable manifest (crashed mid-rename, partial
    copy) are skipped rather than trusted by name."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        try:
            step = int(d.split("_")[1])
        except (IndexError, ValueError):
            continue
        if _read_manifest(os.path.join(ckpt_dir, d)) is not None:
            steps.append(step)
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = valid_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any, *, shardings: Any = None):
    """Restore into the structure of ``like`` (tree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of
    NamedShardings for the *current* mesh (elastic reshard-on-restore).

    Raises :class:`CheckpointCorruptError` on checksum mismatch or
    unreadable files, :class:`StructureMismatchError` if the stored tree
    does not match ``like`` (naming the first mismatched path)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = _read_manifest(d)
    if manifest is None:
        raise CheckpointCorruptError(
            f"checkpoint {d}: manifest.json missing or unreadable")
    try:
        data = np.load(os.path.join(d, "arrays.npz"))
    except Exception as e:      # zipfile.BadZipFile, OSError, ValueError...
        raise CheckpointCorruptError(f"checkpoint {d}: arrays.npz "
                                     f"unreadable: {e}") from e
    leaves, paths, treedef = _flatten(like)
    if paths != manifest["paths"]:
        stored = manifest["paths"]
        for i in range(max(len(paths), len(stored))):
            want = paths[i] if i < len(paths) else "<missing>"
            got = stored[i] if i < len(stored) else "<missing>"
            if want != got:
                raise StructureMismatchError(
                    f"checkpoint {d}: structure mismatch at leaf {i}: "
                    f"model has {want!r}, checkpoint has {got!r} "
                    f"({len(paths)} vs {len(stored)} leaves)")
    checksums = manifest.get("checksums")
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(leaves))
    out = []
    for i, (leaf, sh) in enumerate(zip(leaves, sh_leaves)):
        try:
            raw = data[f"a{i}"]
        except Exception as e:  # missing member, bad zip CRC, truncation
            raise CheckpointCorruptError(
                f"checkpoint {d}: array a{i} ({paths[i]}) unreadable: "
                f"{e}") from e
        if checksums is not None and _crc(raw) != checksums[i]:
            raise CheckpointCorruptError(
                f"checkpoint {d}: checksum mismatch on a{i} ({paths[i]})")
        arr = _from_storable(raw, manifest["dtypes"][i])
        expect = tuple(leaf.shape)
        if arr.shape != expect:
            raise StructureMismatchError(
                f"checkpoint {d}: shape mismatch at {paths[i]}: "
                f"stored {arr.shape}, model expects {expect}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest_valid(ckpt_dir: str, like: Any, *, shardings: Any = None
                         ) -> Tuple[Optional[int], Any]:
    """Restore the newest checkpoint that passes integrity checks.

    Walks steps newest-first; corrupt / torn steps are skipped (counted
    as ``resilience.ckpt.corrupt_skipped``), and so are steps whose tree
    does not match ``like`` — a stale checkpoint from an older model
    config left in the same dir must not kill a restart or rollback
    (counted separately as ``resilience.ckpt.structure_skipped``).
    Returns ``(step, tree)`` or ``(None, None)`` when nothing valid
    exists."""
    for step in reversed(valid_steps(ckpt_dir)):
        try:
            return step, restore(ckpt_dir, step, like, shardings=shardings)
        except CheckpointCorruptError as e:
            obs.get_registry().counter(
                "resilience.ckpt.corrupt_skipped").inc()
            log.warning("skipping corrupt checkpoint: %s", e)
        except StructureMismatchError as e:
            obs.get_registry().counter(
                "resilience.ckpt.structure_skipped").inc()
            log.warning("skipping structure-mismatched checkpoint: %s", e)
    return None, None


class AsyncCheckpointer:
    """One-deep async write queue: snapshot to host, write on a thread.
    ``wait()`` blocks until the in-flight write lands (call before exit).

    A failed write no longer dies silently on the worker thread: the
    exception is captured (counted as ``resilience.ckpt.write_failures``)
    and re-raised from the next ``wait()`` or ``save()`` call, so the
    training loop decides the recovery policy."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._exc: Optional[BaseException] = None
        os.makedirs(ckpt_dir, exist_ok=True)
        cleanup_stale_tmp(ckpt_dir)

    def _write(self, step: int, tree: Any, reg) -> None:
        # route the worker thread's metrics (and injected faults) into the
        # registry that was active on the thread that called save()
        with obs.scoped(reg):
            try:
                save(self.dir, step, tree, keep=self.keep)
            except BaseException as e:                     # noqa: BLE001
                self._exc = e
                reg.counter("resilience.ckpt.write_failures").inc()

    def save(self, step: int, tree: Any) -> None:
        self.wait()                 # surfaces a prior failed write
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._thread = threading.Thread(
            target=self._write,
            args=(step, host_tree, obs.get_registry()), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc
