"""Shared layers: norms, RoPE, embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ init
def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ----------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(dt)


def init_norm(cfg, dtype=jnp.float32):
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.zeros((cfg.d_model,), dtype)}


def apply_norm(params, cfg, x):
    if cfg.norm_type == "layernorm":
        return layernorm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rmsnorm(x, params["scale"], cfg.norm_eps)


# ------------------------------------------------------------------ RoPE
def rope_angles(pos: jax.Array, dh_rot: int, theta: float) -> jax.Array:
    """pos: [...]; returns [..., dh_rot//2] angles."""
    half = dh_rot // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return pos.astype(jnp.float32)[..., None] * freq


def apply_rope(x: jax.Array, pos: jax.Array, theta: float,
               rotary_pct: float = 1.0) -> jax.Array:
    """x: [B, S, H, dh]; pos: [B, S] (or [S]). Split-half (NeoX) convention;
    only the first ``rotary_pct * dh`` dims are rotated (partial rotary)."""
    dh = x.shape[-1]
    dh_rot = int(dh * rotary_pct)
    dh_rot -= dh_rot % 2
    if dh_rot == 0:
        return x
    if pos.ndim == 1:
        pos = pos[None, :]
    ang = rope_angles(pos, dh_rot, theta)          # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    xr, xp = x[..., :dh_rot], x[..., dh_rot:]
    x1, x2 = jnp.split(xr, 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin,
                               x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated, xp], axis=-1)


# ------------------------------------------------------------- embedding
def init_embedding(key, cfg):
    return {"table": embed_init(key, cfg.padded_vocab, cfg.d_model,
                                cfg.jnp_dtype)}


def embed_tokens(params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def logits_from_hidden(table: jax.Array, x: jax.Array) -> jax.Array:
    """x: [..., d] @ table.T -> [..., padded_vocab]."""
    return jnp.einsum("...d,vd->...v", x, table,
                      preferred_element_type=jnp.float32)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def maybe_scan(body, carry, xs, unroll: bool = False):
    """lax.scan, or an unrolled python loop when ``unroll``.

    Unrolling exists for the dry-run cost-analysis pass: XLA's
    cost_analysis counts a while-loop body ONCE regardless of trip count,
    so roofline lowering unrolls every scan to get true FLOPs/bytes.
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def sinusoidal_pos_emb(s: int, d: int, dtype=jnp.float32) -> jax.Array:
    """[S, d] fixed sinusoidal embedding (whisper-style frontends)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = jnp.arange(s)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
