"""Feed-forward blocks: dense (SwiGLU / GeLU) and sort-based MoE dispatch.

The MoE path uses sort-based dispatch (MaxText-style): top-k expert ids are
sorted, positions-within-expert computed from segment offsets, tokens
scattered into a static [E, C, d] buffer, expert matmuls run as one grouped
einsum, and results combine back weighted by the router gate.  One-hot
[n, E, C] dispatch tensors (GShard style) would be O(n^2)-ish at our token
counts; sort-based is O(nk log nk).

MoE + MCA (beyond-paper): the router gate probability is an a-priori
importance signal exactly like attention colmax, so expert up-projections
can run under the per-token Monte-Carlo estimator ("expert_ffn" site).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import amm, dispatch as mca_dispatch, schedule
from repro.dist.context import DP, constrain
from .common import dense_init, gelu


def _zero_stats():
    return {"exact_flops": jnp.zeros((), jnp.float32),
            "mca_flops": jnp.zeros((), jnp.float32)}


# ------------------------------------------------------------- dense FFN
def init_ffn(key, cfg):
    ks = jax.random.split(key, 3)
    dt = cfg.jnp_dtype
    p = {"w_up": dense_init(ks[0], cfg.d_model, cfg.d_ff, dt),
         "w_down": dense_init(ks[1], cfg.d_ff, cfg.d_model, dt)}
    if cfg.ffn_type == "swiglu":
        p["w_gate"] = dense_init(ks[2], cfg.d_model, cfg.d_ff, dt)
    return p


def ffn(p, cfg, x):
    if cfg.ffn_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = gelu(x @ p["w_up"])
    if cfg.attn_parallel != "dp":
        h = constrain(h, DP, None, "model")
    return h @ p["w_down"]


# ------------------------------------------------------------------- MoE
def init_moe(key, cfg):
    ks = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_up": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                 * scale_in).astype(dt),
        "w_down": (jax.random.normal(ks[2], (e, f, d), jnp.float32)
                   * scale_out).astype(dt),
    }
    if cfg.ffn_type == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32)
                       * scale_in).astype(dt)
    return p


def moe_capacity(cfg, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_ffn(p, cfg, x, *, mca_key=None):
    """x: [B, S, d] -> (y, aux_loss, stats).

    Under a mesh this runs as shard-local dispatch inside shard_map: each
    (pod, data, model) shard routes its own tokens with local capacity and
    replicated expert weights (all-gathered at entry — experts here are
    small relative to dispatch traffic).  A global sort-based dispatch
    under GSPMD replicates [n*k, d] gathers across the mesh (measured
    ~180GB/device on granite train_4k); shard-local dispatch eliminates
    that entirely.  Without a mesh (tests/CPU) it is plain local dispatch.
    """
    from repro.dist.context import dp_axes, get_mesh
    mesh = get_mesh()
    if mesh is not None and mesh.size > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        dp = dp_axes(mesh)
        dpe = dp[0] if len(dp) == 1 else dp
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        nm = mesh.shape.get("model", 1)
        b, s, _ = x.shape
        batch_ok = b % n_dp == 0
        seq_ok = s % nm == 0
        if batch_ok:
            x_spec = P(dpe, "model" if seq_ok else None, None)
            key = (mca_key if mca_key is not None
                   else jax.random.PRNGKey(0))
            axes = tuple(a for a in mesh.axis_names
                         if a in dp or (seq_ok and a == "model"))

            def local_fn(p_l, x_l, key_l):
                y, aux, stats = _moe_local(p_l, cfg, x_l, key_l
                                           if mca_key is not None else None)
                aux = jax.lax.pmean(aux, axes)
                stats = jax.tree.map(lambda v: jax.lax.psum(v, axes), stats)
                return y, aux, stats

            return shard_map(
                local_fn, mesh=mesh,
                in_specs=(P(), x_spec, P()),
                out_specs=(x_spec, P(), P()),
                check_rep=False)(p, x, key)
    return _moe_local(p, cfg, x, mca_key)


def _moe_local(p, cfg, x, mca_key=None):
    """Dispatch + expert compute over the (local) token set."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # [n, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)                      # [n, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)      # renormalize

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eid, e, dtype=jnp.float32), axis=1), axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce / k)

    cap = moe_capacity(cfg, n)
    nk = n * k
    flat_e = eid.reshape(nk)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    flat_gate = gate.reshape(nk)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos = jnp.arange(nk) - starts[sorted_e]                  # rank in expert
    fit = pos < cap
    # scatter tokens into [E, C+1, d]; slot C is the overflow trash row
    slot = jnp.where(fit, pos, cap)
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[sorted_e, slot].add(xf[flat_tok[order]])

    xe = buf[:, :cap]                                        # [E, C, d]
    stats = _zero_stats()
    if cfg.mca.active("expert_ffn") and mca_key is not None:
        h_up, st = _mca_expert_matmul(mca_key, cfg, xe, p["w_up"],
                                      sorted_e, slot, flat_gate[order],
                                      cap, s)
        stats = {"exact_flops": stats["exact_flops"] + st["exact_flops"],
                 "mca_flops": stats["mca_flops"] + st["mca_flops"]}
    else:
        h_up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"],
                          preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.ffn_type == "swiglu":
        h_gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"],
                            preferred_element_type=jnp.float32
                            ).astype(x.dtype)
        h = jax.nn.silu(h_gate) * h_up
    else:
        h = gelu(h_up)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                       preferred_element_type=jnp.float32).astype(x.dtype)

    # combine: gather each (token, k) result and weight by gate
    gathered = out_e[sorted_e, jnp.where(fit, pos, 0)]       # [nk, d]
    gathered = jnp.where(fit[:, None], gathered, 0.0)
    contrib = gathered * flat_gate[order][:, None].astype(x.dtype)
    y = jnp.zeros((n, d), x.dtype).at[flat_tok[order]].add(contrib)
    return y.reshape(b, s, d), aux, stats


def _mca_expert_matmul(key, cfg, xe, w_up, sorted_e, slot, gate_sorted,
                       cap, seq_len):
    """Per-expert Monte-Carlo up-projection driven by router gates.

    Importance of a dispatched slot is its gate probability; Eq. 9 turns it
    into a per-slot sample budget, evaluated with the per-token estimator
    vmapped over experts."""
    e, c, d = xe.shape
    f = w_up.shape[-1]
    block = cfg.mca.block_for(d)
    # importance per [E, C] slot (0 for unfilled slots -> min samples)
    imp = jnp.zeros((e, cap + 1), jnp.float32).at[sorted_e, slot].max(
        gate_sorted)[:, :cap]
    r_cols = schedule.r_cols_from_attention(imp, seq_len, cfg.mca.alpha, d)
    r_blocks = schedule.r_blocks_from_cols(r_cols, block)    # [E, C]

    keys = jax.random.split(key, e)
    out = jax.vmap(
        lambda kk, xx, ww, rr: mca_dispatch.per_token_mca_matmul(
            kk, xx, ww, rr, block))(keys, xe, w_up, r_blocks)
    mca_fl = amm.sampled_flops(r_blocks.reshape(-1), f, block)
    stats = {"exact_flops": jnp.asarray(amm.exact_flops(e * c, d, f),
                                        jnp.float32),
             "mca_flops": jnp.asarray(mca_fl, jnp.float32)}
    return out.astype(xe.dtype), stats
