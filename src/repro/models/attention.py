"""Attention: memory-efficient chunked softmax attention (pure JAX, XLA-
lowerable on any backend) with the MCA hooks, plus GQA / MLA modules and
KV-cache decode paths.

Layout convention: activations are [B, S, H, dh] (seq-major); GQA never
materializes repeated KV (einsum over grouped heads).

The chunked two-pass structure mirrors kernels/flash_attention.py +
kernels/attn_colmax.py; on TPU the Pallas kernels replace passes 1+2 (the
wrapper picks the implementation), on CPU/dry-run the lax.scan path lowers
to HLO that XLA pipelines, with identical FLOPs/bytes structure.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import MCAConfig, mca_project
from repro.dist.context import (DP, constrain, constrain_heads,
                                get_mesh)
from repro.kernels import ops as kernel_ops
from .common import apply_rope, dense_init, maybe_scan, rmsnorm

NEG_INF = -1e30


def pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target."""
    c = min(target, s)
    while s % c != 0:
        c -= 1
    return c


def _mask(qpos, kpos, causal: bool, window: int):
    """qpos: [Sq], kpos: [C] -> bool [Sq, C] (True = attend)."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


def _scores(q, k_chunk, scale):
    """q: [B,Sq,Hkv,G,dh]; k_chunk: [B,C,Hkv,dh] -> [B,Hkv,G,Sq,C] f32."""
    return jnp.einsum("bqhgd,bchd->bhgqc", q, k_chunk,
                      preferred_element_type=jnp.float32) * scale


def _kv_chunks(x, chunk):
    b, s, h, d = x.shape
    return jnp.moveaxis(x.reshape(b, s // chunk, chunk, h, d), 1, 0)


# --------------------------------------------------------- chunked passes
def chunked_lse(q, k, *, scale, causal, window, chunk, q_offset=0,
                unroll=False, kv_valid=None):
    """Pass 1: per-query (m, lse). q: [B,Sq,Hkv,G,dh]; k: [B,Skv,Hkv,dh].

    kv_valid: optional [B, Skv] bool — False marks left-padding keys that
    must contribute nothing (score forced to NEG_INF).
    Returns (m, lse), each [B,Hkv,G,Sq] float32.
    """
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    qpos = q_offset + jnp.arange(sq)
    kcs = _kv_chunks(k, chunk)

    def step(carry, inp):
        m, l = carry
        ci, kc = inp
        s = _scores(q, kc, scale)
        kpos = ci * chunk + jnp.arange(chunk)
        s = jnp.where(_mask(qpos, kpos, causal, window)[None, None, None],
                      s, NEG_INF)
        if kv_valid is not None:
            kvc = jax.lax.dynamic_slice_in_dim(kv_valid, ci * chunk, chunk,
                                               axis=1)
            s = jnp.where(kvc[:, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.sum(jnp.exp(s - m_new[..., None]),
                                             axis=-1)
        return (m_new, l), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    (m, l), _ = maybe_scan(jax.checkpoint(step), (m0, l0),
                           (jnp.arange(skv // chunk), kcs), unroll)
    safe_l = jnp.where(l == 0.0, 1.0, l)
    return m, m + jnp.log(safe_l)


def chunked_colmax(q, k, lse, *, scale, causal, window, chunk,
                   q_offset=0, unroll=False, kv_valid=None, q_valid=None):
    """max_i A[i, j] given lse — the Eq. 9 driver. Returns [B, Skv] f32.

    kv_valid ([B, Skv]) zeroes padding key columns; q_valid ([B, Sq])
    excludes padding query rows (their lse is garbage) from the max.
    """
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    qpos = q_offset + jnp.arange(sq)
    kcs = _kv_chunks(k, chunk)

    def step(_, inp):
        ci, kc = inp
        s = _scores(q, kc, scale)
        a = jnp.exp(s - lse[..., None])
        kpos = ci * chunk + jnp.arange(chunk)
        a = jnp.where(_mask(qpos, kpos, causal, window)[None, None, None],
                      a, 0.0)
        if kv_valid is not None:
            kvc = jax.lax.dynamic_slice_in_dim(kv_valid, ci * chunk, chunk,
                                               axis=1)
            a = jnp.where(kvc[:, None, None, None, :], a, 0.0)
        if q_valid is not None:
            a = jnp.where(q_valid[:, None, None, :, None], a, 0.0)
        return None, jnp.max(a, axis=(1, 2, 3))        # -> [B, C]

    _, cms = maybe_scan(jax.checkpoint(step), None,
                        (jnp.arange(skv // chunk), kcs), unroll)
    return jnp.moveaxis(cms, 0, 1).reshape(b, skv)


def chunked_av(q, k, v, lse, *, scale, causal, window, chunk,
               q_offset=0, unroll=False, kv_valid=None):
    """Pass 2: O = A @ V given lse. Returns [B,Sq,Hkv,G,dv] in v.dtype.
    (dv may differ from the q/k head dim, e.g. MLA.)"""
    b, sq, hkv, g, _ = q.shape
    dv = v.shape[-1]
    skv = k.shape[1]
    qpos = q_offset + jnp.arange(sq)
    kcs = _kv_chunks(k, chunk)
    vcs = _kv_chunks(v, chunk)

    def step(acc, inp):
        ci, kc, vc = inp
        s = _scores(q, kc, scale)
        a = jnp.exp(s - lse[..., None])
        kpos = ci * chunk + jnp.arange(chunk)
        a = jnp.where(_mask(qpos, kpos, causal, window)[None, None, None],
                      a, 0.0)
        if kv_valid is not None:
            kvc = jax.lax.dynamic_slice_in_dim(kv_valid, ci * chunk, chunk,
                                               axis=1)
            a = jnp.where(kvc[:, None, None, None, :], a, 0.0)
        acc += jnp.einsum("bhgqc,bchd->bqhgd", a.astype(v.dtype), vc,
                          preferred_element_type=jnp.float32)
        return acc, None

    acc0 = jnp.zeros((b, sq, hkv, g, dv), jnp.float32)
    acc, _ = maybe_scan(jax.checkpoint(step), acc0,
                        (jnp.arange(skv // chunk), kcs, vcs), unroll)
    return acc.astype(v.dtype)


def onepass_attention(q, k, v, *, scale, causal, window, chunk,
                      q_offset=0, unroll=False, kv_valid=None):
    """Single-pass online-softmax attention (no colmax). Returns
    (out [B,Sq,Hkv,G,dv], m, lse)."""
    b, sq, hkv, g, _ = q.shape
    dv = v.shape[-1]
    skv = k.shape[1]
    qpos = q_offset + jnp.arange(sq)
    kcs = _kv_chunks(k, chunk)
    vcs = _kv_chunks(v, chunk)

    def step(carry, inp):
        m, l, acc = carry
        ci, kc, vc = inp
        s = _scores(q, kc, scale)
        kpos = ci * chunk + jnp.arange(chunk)
        s = jnp.where(_mask(qpos, kpos, causal, window)[None, None, None],
                      s, NEG_INF)
        if kv_valid is not None:
            kvc = jax.lax.dynamic_slice_in_dim(kv_valid, ci * chunk, chunk,
                                               axis=1)
            s = jnp.where(kvc[:, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        # correction broadcast to [B,Sq,Hkv,G,1]
        corr_b = jnp.moveaxis(corr, -1, 1)[..., None]
        acc = acc * corr_b + jnp.einsum(
            "bhgqc,bchd->bqhgd", p.astype(v.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, hkv, g, dv), jnp.float32)
    (m, l, acc), _ = maybe_scan(jax.checkpoint(step), (m0, l0, acc0),
                                (jnp.arange(skv // chunk), kcs, vcs), unroll)
    safe_l = jnp.where(l == 0.0, 1.0, l)
    out = acc / jnp.moveaxis(safe_l, -1, 1)[..., None]
    return out.astype(v.dtype), m, m + jnp.log(safe_l)


def chunked_lse_colmax_fused(q, k, *, scale, causal, window, chunk,
                             q_offset=0, unroll=False, kv_valid=None,
                             q_valid=None):
    """One-pass lse + CONSERVATIVE colmax (beyond-paper optimization).

    True colmax needs the final lse (a second O(S^2) sweep). Folding
    max_i exp(s_ij - lse_running_i) during pass 1 uses a *partial* lse
    (<= final), so the result OVERestimates every column max: Eq.9 then
    allocates at least as many samples as the exact schedule and the
    Theorem-2 bound is preserved — at zero extra score sweeps.

    Returns (m, lse, colmax_over [B,Skv])."""
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    qpos = q_offset + jnp.arange(sq)
    kcs = _kv_chunks(k, chunk)

    def step(carry, inp):
        m, l = carry
        ci, kc = inp
        s = _scores(q, kc, scale)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = _mask(qpos, kpos, causal, window)[None, None, None]
        if kv_valid is not None:
            kvc = jax.lax.dynamic_slice_in_dim(kv_valid, ci * chunk, chunk,
                                               axis=1)
            mask = mask & kvc[:, None, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.sum(jnp.exp(s - m_new[..., None]),
                                             axis=-1)
        lse_run = m_new + jnp.log(jnp.where(l == 0, 1.0, l))
        a_over = jnp.exp(s - lse_run[..., None])
        a_over = jnp.where(mask, a_over, 0.0)
        if q_valid is not None:
            a_over = jnp.where(q_valid[:, None, None, :, None], a_over, 0.0)
        cm = jnp.max(a_over, axis=(1, 2, 3))           # [B, C]
        return (m_new, l), cm

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    (m, l), cms = maybe_scan(jax.checkpoint(step), (m0, l0),
                             (jnp.arange(skv // chunk), kcs), unroll)
    safe_l = jnp.where(l == 0.0, 1.0, l)
    colmax = jnp.minimum(jnp.moveaxis(cms, 0, 1).reshape(b, skv), 1.0)
    return m, m + jnp.log(safe_l), colmax


# ------------------------------------------------------- banded (local)
def _band_starts(sq, window, cq):
    band = window + cq
    idx = jnp.arange(sq // cq)
    return jnp.clip((idx + 1) * cq - band, 0, None), band


def banded_lse_colmax(q, k, *, scale, window, chunk_q, unroll=False):
    """Local-attention pass over gathered KV bands: each query chunk of
    size Cq attends only its [qpos-W, qpos] band (length W+Cq), so no
    out-of-window score is ever computed — O(S*(W+Cq)) instead of O(S^2).

    Because the band covers every key a query may attend, lse is final in
    ONE pass and colmax comes for free (exp(s - lse) folded per band with
    a scatter-max over key positions).

    Returns (m, lse [B,Hkv,G,Sq], colmax [B,Skv])."""
    b, sq, hkv, g, dh = q.shape
    starts, band = _band_starts(sq, window, chunk_q)
    nc = sq // chunk_q

    def step(_, inp):
        i, start = inp
        qs = jax.lax.dynamic_slice_in_dim(q, i * chunk_q, chunk_q, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        s = _scores(qs, kb, scale)                   # [B,hkv,g,Cq,band]
        qpos = i * chunk_q + jnp.arange(chunk_q)
        kpos = start + jnp.arange(band)
        mask = (qpos[:, None] >= kpos[None, :]) & \
               (qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        l = jnp.sum(jnp.exp(s - m[..., None]), axis=-1)
        lse = m + jnp.log(jnp.where(l == 0, 1.0, l))
        a = jnp.exp(s - lse[..., None])
        a = jnp.where(mask[None, None, None], a, 0.0)
        cm_band = jnp.max(a, axis=(1, 2, 3))         # [B, band]
        return None, (m, lse, cm_band)

    _, (ms, lses, cms) = maybe_scan(step, None,
                                    (jnp.arange(nc), starts), unroll)
    m = jnp.moveaxis(ms, 0, -2).reshape(b, hkv, g, sq)
    lse = jnp.moveaxis(lses, 0, -2).reshape(b, hkv, g, sq)
    # scatter-max band colmaxes onto absolute key positions
    kpos = starts[:, None] + jnp.arange(band)[None, :]       # [nc, band]
    colmax = jnp.zeros((b, sq), jnp.float32).at[
        :, kpos.reshape(-1)].max(
        jnp.moveaxis(cms, 0, 1).reshape(b, -1))
    return m, lse, colmax


def banded_av(q, k, v, lse, *, scale, window, chunk_q, unroll=False):
    """O = A @ V over gathered bands given final lse."""
    b, sq, hkv, g, dh = q.shape
    dv = v.shape[-1]
    starts, band = _band_starts(sq, window, chunk_q)
    nc = sq // chunk_q

    def step(_, inp):
        i, start = inp
        qs = jax.lax.dynamic_slice_in_dim(q, i * chunk_q, chunk_q, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        s = _scores(qs, kb, scale)
        qpos = i * chunk_q + jnp.arange(chunk_q)
        kpos = start + jnp.arange(band)
        mask = (qpos[:, None] >= kpos[None, :]) & \
               (qpos[:, None] - kpos[None, :] < window)
        lse_c = jax.lax.dynamic_slice_in_dim(lse, i * chunk_q, chunk_q,
                                             axis=-1)
        a = jnp.exp(s - lse_c[..., None])
        a = jnp.where(mask[None, None, None], a, 0.0)
        out = jnp.einsum("bhgqc,bchd->bqhgd", a.astype(v.dtype), vb,
                         preferred_element_type=jnp.float32)
        return None, out.astype(v.dtype)

    _, outs = maybe_scan(step, None, (jnp.arange(nc), starts), unroll)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, hkv, g, dv)


def banded_onepass(q, k, v, *, scale, window, chunk_q, unroll=False):
    """MCA-off local attention: out + (m, lse) over bands (two cheap
    band passes; still ~W/S of the full-scores cost)."""
    m, lse, _ = banded_lse_colmax(q, k, scale=scale, window=window,
                                  chunk_q=chunk_q, unroll=unroll)
    out = banded_av(q, k, v, lse, scale=scale, window=window,
                    chunk_q=chunk_q, unroll=unroll)
    return out, m, lse


def _use_banded(cfg, window, skv, causal, kv_x):
    cq = pick_chunk(skv, cfg.attn_chunk)
    return (cfg.banded_local and window > 0 and causal and kv_x is None
            and skv % cq == 0 and skv >= window + cq)


# ------------------------------------------------------------ GQA module
def init_gqa(key, cfg):
    ks = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.d_head, dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, dt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, dt),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.d_head, cfg.d_model, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.d_head,), jnp.float32)
        p["k_norm"] = jnp.zeros((cfg.d_head,), jnp.float32)
    return p


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _zero_stats(n_tiers: int):
    """Per-layer MCA stats accumulator; tier_hist is padded to the static
    cfg.mca.n_tiers length so it survives lax.scan carries."""
    return {"exact_flops": jnp.zeros((), jnp.float32),
            "mca_flops": jnp.zeros((), jnp.float32),
            "tier_hist": jnp.zeros((n_tiers,), jnp.float32)}


def _acc_stats(acc, s):
    out = {"exact_flops": acc["exact_flops"] + jnp.asarray(
               s["exact_flops"], jnp.float32),
           "mca_flops": acc["mca_flops"] + jnp.asarray(
               s["mca_flops"], jnp.float32),
           "tier_hist": acc["tier_hist"]}
    if "tier_hist" in s:
        # the ladder may be shorter than n_tiers for small d; pad with
        # zeros at the exact end
        h = jnp.asarray(s["tier_hist"], jnp.float32)
        out["tier_hist"] = out["tier_hist"].at[:h.shape[0]].add(h)
    return out


def gqa_attention(p, cfg, x, *, pos, mca_key=None, causal=None,
                  window=None, kv_x=None, return_kv=False, kv_valid=None):
    """Full-sequence (train / prefill) GQA attention with MCA on V/O.

    x: [B, S, d]; kv_x: cross-attention source (defaults to x);
    kv_valid: optional [B, S] bool marking real (non-left-padding) tokens
    of the self-attention sequence — padding keys are masked out of
    scores/colmax and padding query rows out of rowmax.
    Returns (y, kv_or_None, stats).
    """
    causal = cfg.causal if causal is None else causal
    window = cfg.window if window is None else window
    b, sq, d = x.shape
    src = x if kv_x is None else kv_x
    skv = src.shape[1]
    hkv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    dh = cfg.d_head
    scale = dh ** -0.5
    stats = _zero_stats(cfg.mca.n_tiers)
    # in self-attention, query validity is key validity
    q_valid = kv_valid if kv_x is None else None
    # TP-friendly head grouping: when KV heads can't shard over "model" but
    # the full q-head count can, repeat KV to H heads (g=1) so the single
    # head dim shards cleanly (Megatron GQA-TP; repeat is a local
    # broadcast of replicated KV, not a collective).
    mesh = get_mesh()
    nm = mesh.shape.get("model", 1) if mesh is not None else 1
    shardable = cfg.n_heads % nm == 0 or hkv % nm == 0
    seq_par = nm > 1 and (cfg.attn_parallel in ("seq", "dp") or
                          (cfg.attn_parallel == "auto" and not shardable))
    repeat_kv = (not seq_par and nm > 1 and hkv % nm != 0
                 and cfg.n_heads % nm == 0 and g > 1)

    q = _split_heads(x @ p["wq"], cfg.n_heads, dh)
    k = _split_heads(src @ p["wk"], hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    kv_pos = jnp.arange(skv) if kv_x is not None else pos
    q = apply_rope(q, pos, cfg.rope_theta, cfg.rotary_pct)
    k = apply_rope(k, kv_pos, cfg.rope_theta, cfg.rotary_pct)
    k_cache = k
    if repeat_kv:
        k = jnp.repeat(k, g, axis=2)
        hkv_eff, g_eff = cfg.n_heads, 1
    else:
        hkv_eff, g_eff = hkv, g
    if seq_par:
        # sequence-parallel attention: queries stay seq-sharded, KV is
        # gathered (replicated) — scores/softmax/AV are shard-local.
        # Indivisible seq (whisper's 1500 frames): batch over all axes.
        qg = q.reshape(b, sq, hkv_eff, g_eff, dh)
        if sq % nm == 0 and cfg.attn_parallel != "dp":
            k = constrain(k, DP, None, None, None)
            qg = constrain(qg, DP, "model", None, None, None)
        else:
            from repro.dist.context import DPM
            k = constrain(k, DPM, None, None, None)
            qg = constrain(qg, DPM, None, None, None, None)
    else:
        k = constrain_heads(k, head_dims=(2,))
        qg = q.reshape(b, sq, hkv_eff, g_eff, dh)
        qg = constrain_heads(qg, head_dims=(2, 3))

    chunk = pick_chunk(skv, cfg.attn_chunk)
    mca_v = cfg.mca.active("v_proj") and mca_key is not None
    # the banded gather path has no padding-mask support; fall back to the
    # chunked passes for ragged (left-padded) batches
    banded = _use_banded(cfg, window, skv, causal, kv_x) and kv_valid is None

    if mca_v:
        if banded:
            m, lse, colmax = banded_lse_colmax(
                qg, k, scale=scale, window=window, chunk_q=chunk,
                unroll=cfg.unroll_inner)
        elif cfg.mca.fast_colmax:
            m, lse, colmax = chunked_lse_colmax_fused(
                qg, k, scale=scale, causal=causal, window=window,
                chunk=chunk, unroll=cfg.unroll_inner, kv_valid=kv_valid,
                q_valid=q_valid)
        else:
            m, lse = chunked_lse(qg, k, scale=scale, causal=causal,
                                 window=window, chunk=chunk,
                                 unroll=cfg.unroll_inner, kv_valid=kv_valid)
            colmax = chunked_colmax(qg, k, lse, scale=scale, causal=causal,
                                    window=window, chunk=chunk,
                                    unroll=cfg.unroll_inner,
                                    kv_valid=kv_valid, q_valid=q_valid)
        kv, s_v = mca_project(jax.random.fold_in(mca_key, 1), src, p["wv"],
                              colmax, skv, cfg.mca, "v_proj")
        stats = _acc_stats(stats, s_v)
        v_cache = _split_heads(kv, hkv, dh)
        v = jnp.repeat(v_cache, g, axis=2) if repeat_kv else v_cache
        if banded:
            out = banded_av(qg, k, v, lse, scale=scale, window=window,
                            chunk_q=chunk, unroll=cfg.unroll_inner)
        else:
            out = chunked_av(qg, k, v, lse, scale=scale, causal=causal,
                             window=window, chunk=chunk,
                             unroll=cfg.unroll_inner, kv_valid=kv_valid)
        rowmax = jnp.exp(jnp.max(m - lse, axis=(1, 2)))     # [B, Sq]
    else:
        v_cache = _split_heads(src @ p["wv"], hkv, dh)
        v = jnp.repeat(v_cache, g, axis=2) if repeat_kv else v_cache
        if banded:
            out, m, lse = banded_onepass(qg, k, v, scale=scale,
                                         window=window, chunk_q=chunk,
                                         unroll=cfg.unroll_inner)
        else:
            out, m, lse = onepass_attention(
                qg, k, v, scale=scale, causal=causal, window=window,
                chunk=chunk, unroll=cfg.unroll_inner, kv_valid=kv_valid)
        rowmax = jnp.exp(jnp.max(m - lse, axis=(1, 2)))
    if q_valid is not None:
        # padding query rows carry garbage lse; zero importance keeps them
        # in the cheapest tier and out of capacity competition
        rowmax = jnp.where(q_valid, rowmax, 0.0)

    out = out.reshape(b, sq, cfg.n_heads * dh)
    if cfg.mca.active("o_proj") and mca_key is not None:
        y, s_o = mca_project(jax.random.fold_in(mca_key, 2), out, p["wo"],
                             rowmax, sq, cfg.mca, "o_proj")
        stats = _acc_stats(stats, s_o)
    else:
        y = out @ p["wo"]

    # cache holds the (possibly MCA-encoded) V at the ORIGINAL kv-head
    # count — decode reuses H-tilde, matching Y = A @ H-tilde semantics.
    kv_out = (k_cache, v_cache) if return_kv else None
    return y, kv_out, stats, rowmax


# ------------------------------------------------------------ GQA decode
def init_gqa_cache(cfg, batch, max_len, dtype):
    w = cfg.window if cfg.window > 0 else 0
    slots = w if w else max_len
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.d_head), dtype),
        "slot_pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def _decode_attn_chunked(qg, kc, vc, valid, scale, chunk):
    """Flash-decode: online softmax over cache-slot chunks.

    Avoids materializing the [B,Hkv,G,1,slots] f32 score buffer that
    dominates decode temp memory at 32k+ contexts (measured 19.4 GB on
    qwen3 decode_32k with the monolithic softmax).

    qg: [B,1,hkv,g,dh]; kc/vc: [B,slots,hkv,dh]; valid: [B, slots] bool.
    Returns (out [B,1,hkv,g,dh], a_max [B,1] rowmax probability)."""
    b = qg.shape[0]
    hkv, g, dh = qg.shape[2], qg.shape[3], qg.shape[4]
    slots = kc.shape[1]

    def step(carry, ci):
        m, l, acc = carry
        # dynamic slices of the (donated) cache — no moveaxis copy
        kcb = jax.lax.dynamic_slice_in_dim(kc, ci * chunk, chunk, axis=1)
        vcb = jax.lax.dynamic_slice_in_dim(vc, ci * chunk, chunk, axis=1)
        vm = jax.lax.dynamic_slice_in_dim(valid, ci * chunk, chunk, axis=1)
        s = jnp.einsum("bqhgd,bchd->bhgqc", qg, kcb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(vm[:, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p_, axis=-1)
        corr_b = jnp.moveaxis(corr, -1, 1)[..., None]
        acc = acc * corr_b + jnp.einsum(
            "bhgqc,bchd->bqhgd", p_.astype(vcb.dtype), vcb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, 1), jnp.float32)
    acc0 = jnp.zeros((b, 1, hkv, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  jnp.arange(slots // chunk))
    safe_l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / jnp.moveaxis(safe_l, -1, 1)[..., None]).astype(vc.dtype)
    # max attention prob per query = exp(m - lse)
    a_max = jnp.max(jnp.exp(m - (m + jnp.log(safe_l))), axis=(1, 2, 3)
                    )[:, None]
    return out, a_max


def gqa_decode(p, cfg, x, cache, *, t, pos_off=None):
    """Single-token decode. x: [B, 1, d]; t: scalar or [B] int32 position.

    A scalar ``t`` is the classic lockstep decode (one shared position); a
    per-row ``t`` vector is the per-slot continuous-batching path, where
    every batch row advances at its own sequence position and K/V land at
    per-row cache slots (``kernels.kv_slot_update``).

    pos_off: optional [B] int32 left-padding offsets — slots whose global
    position predates a batch row's first real token are masked for that
    row, and RoPE positions shift to t - pos_off[b].
    Returns (y, new_cache, rowmax [B,1])."""
    b = x.shape[0]
    hkv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    dh = cfg.d_head
    scale = dh ** -0.5
    slots = cache["k"].shape[1]
    off = jnp.zeros((b,), jnp.int32) if pos_off is None else pos_off
    t_vec = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))

    q = _split_heads(x @ p["wq"], cfg.n_heads, dh)
    k1 = _split_heads(x @ p["wk"], hkv, dh)
    v1 = _split_heads(x @ p["wv"], hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k1 = rmsnorm(k1, p["k_norm"], cfg.norm_eps)
    posb = t_vec[:, None] - off[:, None]
    q = apply_rope(q, posb, cfg.rope_theta, cfg.rotary_pct)
    k1 = apply_rope(k1, posb, cfg.rope_theta, cfg.rotary_pct)

    slot = t_vec % slots if cfg.window > 0 else t_vec
    kc = kernel_ops.kv_slot_update(cache["k"], k1, slot)
    vc = kernel_ops.kv_slot_update(cache["v"], v1, slot)
    spos = cache["slot_pos"].at[jnp.arange(b), slot].set(t_vec)

    qg = q.reshape(b, 1, hkv, g, dh)
    # slot_pos are per-row global (pre-offset) positions, so the rolling-
    # window wraparound composes with the per-row padding mask
    valid = (spos >= 0) & (spos >= off[:, None])
    if slots >= 8192 and slots % 1024 == 0:
        # flash-decode path: never materialize the full score buffer
        out, rowmax = _decode_attn_chunked(qg, kc, vc, valid, scale, 1024)
    else:
        s = jnp.einsum("bqhgd,bshd->bhgqs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqs,bshd->bqhgd", a.astype(vc.dtype), vc)
        rowmax = jnp.max(a, axis=(1, 2, 4))                 # [B, 1]
    out = out.reshape(b, 1, cfg.n_heads * dh)
    y = out @ p["wo"]
    return y, {"k": kc, "v": vc, "slot_pos": spos}, rowmax


# ------------------------------------------------------------ MLA module
def init_mla(key, cfg):
    ks = jax.random.split(key, 7)
    dt = cfg.jnp_dtype
    h = cfg.n_heads
    return {
        "w_dq": dense_init(ks[0], cfg.d_model, cfg.mla_q_lora, dt),
        "w_uq": dense_init(ks[1], cfg.mla_q_lora,
                           h * (cfg.mla_qk_nope + cfg.mla_qk_rope), dt),
        "w_dkv": dense_init(ks[2], cfg.d_model, cfg.mla_kv_lora, dt),
        "w_kr": dense_init(ks[3], cfg.d_model, cfg.mla_qk_rope, dt),
        "w_uk": dense_init(ks[4], cfg.mla_kv_lora, h * cfg.mla_qk_nope, dt),
        "w_uv": dense_init(ks[5], cfg.mla_kv_lora, h * cfg.mla_v_dim, dt),
        "wo": dense_init(ks[6], h * cfg.mla_v_dim, cfg.d_model, dt),
        "q_ln": jnp.zeros((cfg.mla_q_lora,), jnp.float32),
        "kv_ln": jnp.zeros((cfg.mla_kv_lora,), jnp.float32),
    }


def mla_attention(p, cfg, x, *, pos, mca_key=None, return_cache=False,
                  kv_valid=None):
    """MLA (latent) attention, full-sequence. MCA applies to the latent
    value up-projection W_UV (importance = colmax) and W_O.

    kv_valid: optional [B, S] bool marking real (non-left-padding) tokens.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.mla_qk_nope, cfg.mla_qk_rope, cfg.mla_v_dim
    scale = (dn + dr) ** -0.5
    stats = _zero_stats(cfg.mca.n_tiers)

    cq = rmsnorm(x @ p["w_dq"], p["q_ln"], cfg.norm_eps)
    q = _split_heads(cq @ p["w_uq"], h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    ckv = rmsnorm(x @ p["w_dkv"], p["kv_ln"], cfg.norm_eps)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], pos,
                        cfg.rope_theta)                     # [B,S,1,dr]
    k_nope = _split_heads(ckv @ p["w_uk"], h, dn)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    qg = q_full.reshape(b, s, h, 1, dn + dr)                # hkv=h, g=1
    mesh = get_mesh()
    nm = mesh.shape.get("model", 1) if mesh is not None else 1
    if nm > 1 and cfg.attn_parallel in ("seq", "auto"):
        # MLA weights are replicated; run attention sequence-parallel
        k = constrain(k, DP, None, None, None)
        qg = constrain(qg, DP, "model", None, None, None)

    chunk = pick_chunk(s, cfg.attn_chunk)
    mca_v = cfg.mca.active("v_proj") and mca_key is not None
    if mca_v:
        m, lse = chunked_lse(qg, k, scale=scale, causal=cfg.causal,
                             window=0, chunk=chunk,
                             unroll=cfg.unroll_inner, kv_valid=kv_valid)
        colmax = chunked_colmax(qg, k, lse, scale=scale, causal=cfg.causal,
                                window=0, chunk=chunk,
                                unroll=cfg.unroll_inner, kv_valid=kv_valid,
                                q_valid=kv_valid)
        hv, s_v = mca_project(jax.random.fold_in(mca_key, 1), ckv, p["w_uv"],
                              colmax, s, cfg.mca, "v_proj")
        stats = _acc_stats(stats, s_v)
        v = _split_heads(hv, h, dv)
        out = chunked_av(qg, k, v, lse, scale=scale, causal=cfg.causal,
                         window=0, chunk=chunk, unroll=cfg.unroll_inner,
                         kv_valid=kv_valid)
        rowmax = jnp.exp(jnp.max(m - lse, axis=(1, 2)))
    else:
        v = _split_heads(ckv @ p["w_uv"], h, dv)
        out, m, lse = onepass_attention(qg, k, v, scale=scale,
                                        causal=cfg.causal, window=0,
                                        chunk=chunk,
                                        unroll=cfg.unroll_inner,
                                        kv_valid=kv_valid)
        rowmax = jnp.exp(jnp.max(m - lse, axis=(1, 2)))
    if kv_valid is not None:
        rowmax = jnp.where(kv_valid, rowmax, 0.0)

    out = out.reshape(b, s, h * dv)
    if cfg.mca.active("o_proj") and mca_key is not None:
        y, s_o = mca_project(jax.random.fold_in(mca_key, 2), out, p["wo"],
                             rowmax, s, cfg.mca, "o_proj")
        stats = _acc_stats(stats, s_o)
    else:
        y = out @ p["wo"]

    cache = (ckv, k_rope[:, :, 0, :]) if return_cache else None
    return y, cache, stats, rowmax


def init_mla_cache(cfg, batch, max_len, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.mla_kv_lora), dtype),
        "kr": jnp.zeros((batch, max_len, cfg.mla_qk_rope), dtype),
    }


def mla_decode(p, cfg, x, cache, *, t, pos_off=None):
    """Absorbed-matrix MLA decode: scores/value read the latent cache
    directly; per-token cache cost is (kv_lora + rope) floats.

    ``t`` may be a scalar (lockstep decode) or a [B] vector (per-slot
    continuous batching — each row writes/reads at its own position)."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.mla_qk_nope, cfg.mla_qk_rope, cfg.mla_v_dim
    dl = cfg.mla_kv_lora
    scale = (dn + dr) ** -0.5
    off = jnp.zeros((b,), jnp.int32) if pos_off is None else pos_off
    t_vec = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))

    cq = rmsnorm(x @ p["w_dq"], p["q_ln"], cfg.norm_eps)
    q = _split_heads(cq @ p["w_uq"], h, dn + dr)            # [B,1,h,dn+dr]
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posb = t_vec[:, None] - off[:, None]
    q_rope = apply_rope(q_rope, posb, cfg.rope_theta)

    ckv1 = rmsnorm(x @ p["w_dkv"], p["kv_ln"], cfg.norm_eps)  # [B,1,dl]
    kr1 = apply_rope((x @ p["w_kr"])[:, :, None, :], posb,
                     cfg.rope_theta)[:, :, 0, :]              # [B,1,dr]
    ckv = kernel_ops.kv_slot_update(cache["ckv"], ckv1, t_vec)
    kr = kernel_ops.kv_slot_update(cache["kr"], kr1, t_vec)

    # absorb W_UK into the query:  q_lat[b,h,dl] = q_nope . W_UK[:, h, :]
    w_uk = p["w_uk"].reshape(dl, h, dn)
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk)
    s_lat = jnp.einsum("bqhl,bsl->bhqs", q_lat, ckv,
                       preferred_element_type=jnp.float32)
    s_rot = jnp.einsum("bqhd,bsd->bhqs", q_rope, kr,
                       preferred_element_type=jnp.float32)
    s = (s_lat + s_rot) * scale
    idxs = jnp.arange(ckv.shape[1])
    valid = ((idxs[None, :] <= t_vec[:, None])
             & (idxs[None, :] >= off[:, None]))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhqs,bsl->bqhl", a.astype(ckv.dtype), ckv)
    # absorb W_UV on the way out
    w_uv = p["w_uv"].reshape(dl, h, dv)
    out = jnp.einsum("bqhl,lhv->bqhv", out_lat, w_uv).reshape(b, 1, h * dv)
    y = out @ p["wo"]
    rowmax = jnp.max(a, axis=(1, 3))
    return y, {"ckv": ckv, "kr": kr}, rowmax
