"""Layer stacks: init / forward / prefill / decode for every family.

Homogeneous stacks run under jax.lax.scan over stacked per-layer params
(small HLO, fast AOT compile at 512 devices); the hybrid (RecurrentGemma)
stack scans over repeating block-pattern groups with an unrolled remainder.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.context import DP, constrain, constrain_residual
from . import attention as attn
from . import ffn as ffn_mod
from . import rglru, ssm
from .common import apply_norm, init_norm, maybe_scan


def _zero_carry_stats(cfg):
    """Stats carry for the layer scan; tier_hist has the static
    cfg.mca.n_tiers length so the carry pytree is shape-stable."""
    return {"exact_flops": jnp.zeros((), jnp.float32),
            "mca_flops": jnp.zeros((), jnp.float32),
            "tier_hist": jnp.zeros((cfg.mca.n_tiers,), jnp.float32)}


def _add_stats(a, b):
    # MoE stats carry no tier_hist; missing keys contribute zero
    return {k: a[k] + b.get(k, 0.0) for k in a}


# ============================================================ layer kinds
def layer_kind(cfg) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.family == "moe":
        return "attn_moe"
    return "attn_ffn"


def init_layer(key, cfg, kind: str):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": init_norm(cfg)}
    if kind == "ssm":
        p["mixer"] = ssm.init_mamba2(ks[0], cfg)
        return p
    if kind == "rec_ffn":
        p["mixer"] = rglru.init_recurrent_block(ks[0], cfg)
    elif cfg.attn_type == "mla":
        p["mixer"] = attn.init_mla(ks[0], cfg)
    else:
        p["mixer"] = attn.init_gqa(ks[0], cfg)
    p["ln2"] = init_norm(cfg)
    if kind == "attn_moe":
        p["ffn"] = ffn_mod.init_moe(ks[1], cfg)
    else:
        p["ffn"] = ffn_mod.init_ffn(ks[1], cfg)
    if kind == "dec_attn_ffn":                       # cross-attention branch
        p["ln_x"] = init_norm(cfg)
        p["cross"] = attn.init_gqa(ks[2], cfg)
    return p


def layer_forward(p, cfg, x, *, pos, mca_key, kind: str,
                  enc_out=None, causal=None, window=None):
    """One residual block. Returns (x, aux_loss, stats)."""
    aux = jnp.zeros((), jnp.float32)
    stats = _zero_carry_stats(cfg)

    # Megatron-SP: residual stream sharded batch-over-DP and seq-over-model
    # at layer boundaries; GSPMD inserts the all-gather/reduce-scatter pair
    # around attention/FFN. Cuts saved-activation memory n_model-fold.
    x = constrain_residual(x, cfg.attn_parallel)
    h = apply_norm(p["ln1"], cfg, x)
    if kind == "ssm":
        x = x + ssm.mamba2_forward(p["mixer"], cfg, h)
        return x, aux, stats
    if kind == "rec_ffn":
        x = x + rglru.recurrent_block(p["mixer"], cfg, h)
    elif cfg.attn_type == "mla":
        y, _, st, _ = attn.mla_attention(p["mixer"], cfg, h, pos=pos,
                                         mca_key=mca_key)
        stats = _add_stats(stats, st)
        x = x + y
    else:
        y, _, st, _ = attn.gqa_attention(p["mixer"], cfg, h, pos=pos,
                                         mca_key=mca_key, causal=causal,
                                         window=window)
        stats = _add_stats(stats, st)
        x = x + y

    if kind == "dec_attn_ffn" and enc_out is not None:
        h = apply_norm(p["ln_x"], cfg, x)
        y, _, st, _ = attn.gqa_attention(
            p["cross"], cfg, h, pos=pos,
            mca_key=None if mca_key is None else jax.random.fold_in(
                mca_key, 7),
            causal=False, window=0, kv_x=enc_out)
        stats = _add_stats(stats, st)
        x = x + y

    h = apply_norm(p["ln2"], cfg, x)
    if kind == "attn_moe":
        y, aux_l, st = ffn_mod.moe_ffn(p["ffn"], cfg, h, mca_key=mca_key)
        aux = aux + aux_l
        stats = _add_stats(stats, st)
    else:
        y = ffn_mod.ffn(p["ffn"], cfg, h)
    return x + y, aux, stats


# ====================================================== homogeneous stack
def init_stack(key, cfg, n_layers: int, kind: str):
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: init_layer(k, cfg, kind))(keys)


def stack_forward(params, cfg, x, *, pos, mca_key, kind: str, enc_out=None,
                  causal=None, window=None):
    """Scan (or unroll) over layers. Returns (x, aux, stats)."""
    n_layers = jax.tree_util.tree_leaves(params)[0].shape[0]

    def body(carry, inp):
        xx, aux, stats = carry
        p_l, idx = inp
        key_l = None if mca_key is None else jax.random.fold_in(mca_key, idx)
        xx, aux_l, st = layer_forward(p_l, cfg, xx, pos=pos, mca_key=key_l,
                                      kind=kind, enc_out=enc_out,
                                      causal=causal, window=window)
        return (xx, aux + aux_l, _add_stats(stats, st)), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    carry0 = (x, jnp.zeros((), jnp.float32), _zero_carry_stats(cfg))
    if cfg.unroll_layers:
        carry = carry0
        for i in range(n_layers):
            p_l = jax.tree.map(lambda a: a[i], params)
            carry, _ = body_fn(carry, (p_l, jnp.asarray(i)))
        return carry
    (x, aux, stats), _ = jax.lax.scan(
        body_fn, carry0, (params, jnp.arange(n_layers)))
    return x, aux, stats


# ============================================================ hybrid stack
def hybrid_layout(cfg):
    """Returns (n_groups, pattern_kinds, remainder_kinds)."""
    pat = tuple("rec_ffn" if k == "rec" else "attn_ffn"
                for k in cfg.block_pattern)
    n_groups = cfg.n_layers // len(pat)
    rem = cfg.n_layers - n_groups * len(pat)
    return n_groups, pat, pat[:rem]


def init_hybrid(key, cfg):
    n_groups, pat, rem = hybrid_layout(cfg)
    ks = jax.random.split(key, len(pat) + len(rem))
    grouped = {}
    for i, kind in enumerate(pat):
        keys = jax.random.split(ks[i], n_groups)
        grouped[f"pos{i}"] = jax.vmap(
            lambda k: init_layer(k, cfg, kind))(keys)
    remainder = [init_layer(ks[len(pat) + i], cfg, kind)
                 for i, kind in enumerate(rem)]
    return {"groups": grouped, "rem": remainder}


def hybrid_forward(params, cfg, x, *, pos, mca_key):
    n_groups, pat, rem = hybrid_layout(cfg)

    def body(carry, inp):
        xx, aux, stats = carry
        group_params, gidx = inp
        for i, kind in enumerate(pat):
            key_l = None if mca_key is None else jax.random.fold_in(
                mca_key, gidx * len(pat) + i)
            win = cfg.window if kind == "attn_ffn" else 0
            xx, aux_l, st = layer_forward(
                group_params[f"pos{i}"], cfg, xx, pos=pos,
                mca_key=key_l, kind=kind, window=win)
            aux = aux + aux_l
            stats = _add_stats(stats, st)
        return (xx, aux, stats), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    carry0 = (x, jnp.zeros((), jnp.float32), _zero_carry_stats(cfg))
    (x, aux, stats), _ = maybe_scan(
        body_fn, carry0, (params["groups"], jnp.arange(n_groups)),
        cfg.unroll_layers)
    for i, kind in enumerate(rem):
        key_l = None if mca_key is None else jax.random.fold_in(
            mca_key, n_groups * len(pat) + i)
        win = cfg.window if kind == "attn_ffn" else 0
        x, aux_l, st = layer_forward(params["rem"][i], cfg, x, pos=pos,
                                     mca_key=key_l, kind=kind, window=win)
        aux = aux + aux_l
        stats = _add_stats(stats, st)
    return x, aux, stats
