"""Mamba-2 (SSD, state-space duality) layer — chunked scan, pure JAX.

Implements the SSD algorithm of arXiv:2405.21060: intra-chunk quadratic
(semiseparable) term + inter-chunk state recurrence via lax.scan.  MCA is
inapplicable here (no attention matrix — see DESIGN.md §Arch-applicability);
the layer runs exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.context import constrain_heads
from .common import dense_init, maybe_scan, rmsnorm


def init_mamba2(key, cfg):
    ks = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    d_in = cfg.ssm_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = d_in + 2 * g * n
    return {
        "in_proj": dense_init(ks[0], cfg.d_model,
                              2 * d_in + 2 * g * n + h, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": dense_init(ks[3], d_in, cfg.d_model, dt),
    }


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: [B,S,C]; w: [W,C]; left-pad W-1."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(width))
    return out + b[None, None]


def ssd_chunked(xs, dt, a, bmat, cmat, chunk, unroll=False):
    """SSD forward. xs: [B,S,H,P]; dt: [B,S,H]; a: [H] (negative);
    bmat/cmat: [B,S,G,N]; H % G == 0. Returns (y [B,S,H,P], state
    [B,G,HG,N,P] final)."""
    b, s, h, p = xs.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hg = h // g
    q = chunk
    nc = s // q

    da = (dt * a[None, None]).reshape(b, nc, q, g, hg)       # log-decay
    xc = xs.reshape(b, nc, q, g, hg, p)
    dtc = dt.reshape(b, nc, q, g, hg)
    bc = bmat.reshape(b, nc, q, g, n)
    cc = cmat.reshape(b, nc, q, g, n)
    cum = jnp.cumsum(da, axis=2)                             # [b,nc,q,g,hg]

    def step(state, inp):
        cum_c, x_c, dt_c, b_c, c_c = inp                     # chunk tensors
        # intra-chunk (quadratic, causal-masked decay kernel)
        scores = jnp.einsum("bign,bjgn->bijg", c_c, b_c)     # [b,q,q,g]
        ldec = jnp.exp(cum_c[:, :, None] - cum_c[:, None])   # [b,i,j,g,hg]
        mask = jnp.tril(jnp.ones((q, q), bool))
        ldec = jnp.where(mask[None, :, :, None, None], ldec, 0.0)
        xdt = x_c * dt_c[..., None]                          # [b,q,g,hg,p]
        y_intra = jnp.einsum("bijg,bijgh,bjghp->bighp",
                             scores, ldec, xdt)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bign,bghnp->bighp", c_c, state) \
            * jnp.exp(cum_c)[..., None]
        # new state carried out of the chunk
        decay_out = jnp.exp(cum_c[:, -1:, :, :] - cum_c)     # [b,q,g,hg]
        state_c = jnp.einsum("bjgn,bjghp->bghnp",
                             b_c, xdt * decay_out[..., None])
        total = jnp.exp(cum_c[:, -1])                        # [b,g,hg]
        state = state * total[..., None, None] + state_c
        return state, y_intra + y_inter

    state0 = jnp.zeros((b, g, hg, n, p), jnp.float32)
    cum_m = jnp.moveaxis(cum, 1, 0)
    x_m = jnp.moveaxis(xc.astype(jnp.float32), 1, 0)
    dt_m = jnp.moveaxis(dtc, 1, 0)
    b_m = jnp.moveaxis(bc.astype(jnp.float32), 1, 0)
    c_m = jnp.moveaxis(cc.astype(jnp.float32), 1, 0)
    state, ys = maybe_scan(step, state0, (cum_m, x_m, dt_m, b_m, c_m),
                           unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y.astype(xs.dtype), state


def ssd_sequential(xs, dt, a, bmat, cmat):
    """O(S) sequential oracle for tests."""
    b, s, h, p = xs.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hg = h // g

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp                            # [b,g,hg,p] ...
        decay = jnp.exp(dt_t * a.reshape(g, hg)[None])       # [b,g,hg]
        upd = jnp.einsum("bgn,bghp->bghnp", b_t,
                         x_t * dt_t[..., None])
        state = state * decay[..., None, None] + upd
        y_t = jnp.einsum("bgn,bghnp->bghp", c_t, state)
        return state, y_t

    xs_m = jnp.moveaxis(xs.reshape(b, s, g, hg, p).astype(jnp.float32), 1, 0)
    dt_m = jnp.moveaxis(dt.reshape(b, s, g, hg), 1, 0)
    b_m = jnp.moveaxis(bmat.astype(jnp.float32), 1, 0)
    c_m = jnp.moveaxis(cmat.astype(jnp.float32), 1, 0)
    state0 = jnp.zeros((b, g, hg, n, p), jnp.float32)
    state, ys = jax.lax.scan(step, state0, (xs_m, dt_m, b_m, c_m))
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p).astype(xs.dtype), state


def mamba2_forward(p, cfg, x, *, state=None, conv_state=None,
                   return_state=False):
    """Full-sequence Mamba-2 block. x: [B, S, d_model]."""
    b, s, _ = x.shape
    d_in = cfg.ssm_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h = cfg.ssm_heads
    ph = cfg.ssm_headdim

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc_raw = zxbcdt[..., d_in:d_in + d_in + 2 * g * n]
    dt_raw = zxbcdt[..., -h:]
    xbc = jax.nn.silu(causal_conv1d(xbc_raw, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_in].reshape(b, s, h, ph)
    xs = constrain_heads(xs, head_dims=(2,))     # 80 SSD heads over model
    bmat = xbc[..., d_in:d_in + g * n].reshape(b, s, g, n)
    cmat = xbc[..., d_in + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    chunk = min(cfg.ssm_chunk, s)
    while s % chunk != 0:
        chunk //= 2
    y, final_state = ssd_chunked(xs, dt, a, bmat, cmat, chunk,
                                 unroll=cfg.unroll_inner)
    y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(b, s, d_in)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state:
        # decode conv cache = last (conv_width - 1) pre-activation xBC rows
        conv_tail = xbc_raw[:, -(cfg.conv_width - 1):]
        return out, final_state, conv_tail
    return out


def init_mamba2_cache(cfg, batch, dtype):
    d_in = cfg.ssm_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h, ph = cfg.ssm_heads, cfg.ssm_headdim
    hg = h // g
    return {
        "state": jnp.zeros((batch, g, hg, n, ph), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           d_in + 2 * g * n), dtype),
    }


def mamba2_decode(p, cfg, x, cache):
    """Single-token decode. x: [B, 1, d_model]."""
    b = x.shape[0]
    d_in = cfg.ssm_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h, ph = cfg.ssm_heads, cfg.ssm_headdim
    hg = h // g

    zxbcdt = (x @ p["in_proj"])[:, 0]                        # [B, ...]
    z = zxbcdt[..., :d_in]
    xbc_new = zxbcdt[..., d_in:d_in + d_in + 2 * g * n]
    dt_raw = zxbcdt[..., -h:]

    conv_buf = jnp.concatenate([cache["conv"], xbc_new[:, None]], axis=1)
    w = p["conv_w"]
    xbc = jnp.sum(conv_buf * w[None], axis=1) + p["conv_b"][None]
    xbc = jax.nn.silu(xbc)
    new_conv = conv_buf[:, 1:]

    xs = xbc[..., :d_in].reshape(b, g, hg, ph).astype(jnp.float32)
    bmat = xbc[..., d_in:d_in + g * n].reshape(b, g, n).astype(jnp.float32)
    cmat = xbc[..., d_in + g * n:].reshape(b, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"]).reshape(b, g, hg)
    a = -jnp.exp(p["a_log"]).reshape(g, hg)

    decay = jnp.exp(dt * a[None])
    upd = jnp.einsum("bgn,bghp->bghnp", bmat, xs * dt[..., None])
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bgn,bghnp->bghp", cmat, state)
    y = y + p["d_skip"].reshape(g, hg)[None, ..., None] * xs
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z[:, None])
    out = y @ p["out_proj"]
    return out, {"state": state, "conv": new_conv}
