"""Model configuration: one dataclass covers every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.core.policy import MCAConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 32
    d_ff: int = 256
    vocab_size: int = 1024

    # attention flavour
    attn_type: str = "gqa"       # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0      # fraction of head dim rotated (chatglm: 0.5)
    window: int = 0              # 0 = global attention; >0 sliding window
    causal: bool = True

    # MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2 style)
    mla_q_lora: int = 0
    mla_kv_lora: int = 0
    mla_qk_nope: int = 0
    mla_qk_rope: int = 0
    mla_v_dim: int = 0

    # FFN
    ffn_type: str = "swiglu"     # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_groups: int = 1
    conv_width: int = 4

    # hybrid (RecurrentGemma / Griffin)
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    rnn_width: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500      # stub conv-frontend output frames

    # modality frontend stub
    frontend: str = "none"       # none | patch (vlm) | frames (audio)
    n_patch_tokens: int = 256    # vlm stub tokens prepended

    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    norm_eps: float = 1e-6
    add_sinusoidal_pos: bool = False   # absolute pos-emb (BERT-style)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    attn_chunk: int = 512        # kv-chunk for memory-efficient attention
    logits_chunk: int = 512      # seq-chunk for vocab-sharded xent
    unroll_layers: bool = False  # True: python loop + MCA stats (benchmarks)
    unroll_inner: bool = False   # unroll within-layer scans (cost analysis)
    remat: bool = True
    banded_local: bool = False   # gather-banded local attention (skips
                                 # out-of-window KV chunks entirely)
    attn_parallel: str = "auto"  # "tp": heads over model (Megatron);
                                 # "seq": sequence-parallel attention with
                                 # replicated attn weights + gathered KV;
                                 # "auto": seq when no head dim divides the
                                 # model axis, tp otherwise

    mca: MCAConfig = dataclasses.field(default_factory=MCAConfig)

    # ------------------------------------------------------------ helpers
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 for lane alignment + sharding."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def q_dim(self) -> int:
        if self.attn_type == "mla":
            return self.n_heads * (self.mla_qk_nope + self.mla_qk_rope)
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def attn_out_dim(self) -> int:
        if self.attn_type == "mla":
            return self.n_heads * self.mla_v_dim
        return self.n_heads * self.d_head

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 2 if not cfg.block_pattern
                     else len(cfg.block_pattern)),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)
                       if cfg.n_kv_heads < cfg.n_heads else 4),
        d_head=32,
        d_ff=256,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        # drop-free capacity so decode == forward exactly in smoke tests
        capacity_factor=(max(cfg.capacity_factor,
                             min(cfg.n_experts, 4) / min(cfg.top_k, 2))
                         if cfg.n_experts else cfg.capacity_factor),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else 64,
        ssm_chunk=16 if cfg.ssm_state else 64,
        rnn_width=128 if cfg.rnn_width else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_len=32,
        n_patch_tokens=8,
        window=min(cfg.window, 32) if cfg.window else 0,
        mla_q_lora=64 if cfg.mla_q_lora else 0,
        mla_kv_lora=32 if cfg.mla_kv_lora else 0,
        mla_qk_nope=32 if cfg.mla_qk_nope else 0,
        mla_qk_rope=16 if cfg.mla_qk_rope else 0,
        mla_v_dim=32 if cfg.mla_v_dim else 0,
        attn_chunk=64,
        logits_chunk=64,
        dtype="float32",
    )
    if cfg.block_pattern:
        small["block_pattern"] = cfg.block_pattern
    if cfg.mca.enabled:
        small["mca"] = dataclasses.replace(cfg.mca, block=16)
    small.update(overrides)
    return cfg.replace(**small)
