"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
a_t = exp(-c * softplus(Lambda) * r_t),  r_t, i_t input-sigmoid gates.

Full-sequence path uses jax.lax.associative_scan (log-depth on TPU);
decode is the single-step recurrence.  MCA is inapplicable on recurrent
layers (no attention matrix); the hybrid stack applies MCA only on its
local-attention layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.context import DP, constrain
from .common import dense_init, gelu

RG_LRU_C = 8.0


def init_recurrent_block(key, cfg):
    ks = jax.random.split(key, 7)
    dt = cfg.jnp_dtype
    d, dr = cfg.d_model, cfg.rnn_width
    # Lambda init so that a ~ U(0.9, 0.999)^c-ish (Griffin appendix)
    lam = jnp.log(jnp.expm1(
        -jnp.log(jnp.linspace(0.9, 0.999, dr)) / RG_LRU_C))
    return {
        "w_gelu": dense_init(ks[0], d, dr, dt),
        "w_rec": dense_init(ks[1], d, dr, dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, dr),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((dr,), dt),
        "w_a": dense_init(ks[3], dr, dr, dt),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_i": dense_init(ks[4], dr, dr, dt),
        "b_i": jnp.zeros((dr,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[5], dr, d, dt),
    }


def _gates(p, x):
    """x: [..., dr] -> (log_a, gated_input) in f32."""
    r = jax.nn.sigmoid(x.astype(jnp.float32) @ p["w_a"].astype(jnp.float32)
                       + p["b_a"])
    i = jax.nn.sigmoid(x.astype(jnp.float32) @ p["w_i"].astype(jnp.float32)
                       + p["b_i"])
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) \
        * (i * x.astype(jnp.float32))
    return a, gated


def rg_lru(p, x):
    """x: [B, S, dr] -> [B, S, dr]; associative linear recurrence.
    Channels shard over "model" (the recurrence is elementwise)."""
    x = constrain(x, DP, None, "model")
    a, b = _gates(p, x)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    a_cum, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype)


def rg_lru_step(p, x, h_prev):
    """x: [B, dr]; h_prev: [B, dr] f32 -> (y, h)."""
    a, b = _gates(p, x)
    h = a * h_prev + b
    return h.astype(x.dtype), h


def recurrent_block(p, cfg, x):
    """Griffin recurrent block, full sequence. x: [B, S, d_model]."""
    from .ssm import causal_conv1d
    gate = gelu(x @ p["w_gelu"])
    rec_in = x @ p["w_rec"]
    rec = causal_conv1d(rec_in, p["conv_w"], p["conv_b"])
    rec = rg_lru(p, rec)
    y = (gate * rec) @ p["w_out"]
    return y


def recurrent_block_with_state(p, cfg, x):
    """Like recurrent_block but also returns (conv_tail, h_final) for
    prefill -> decode handoff."""
    from .ssm import causal_conv1d
    gate = gelu(x @ p["w_gelu"])
    rec_in = x @ p["w_rec"]
    rec_conv = causal_conv1d(rec_in, p["conv_w"], p["conv_b"])
    a, b = _gates(p, rec_conv)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gate * h.astype(x.dtype)) @ p["w_out"]
    conv_tail = rec_in[:, -(cfg.conv_width - 1):]
    return y, conv_tail, h[:, -1]


def init_recurrent_cache(cfg, batch, dtype):
    return {
        "h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width), dtype),
    }


def recurrent_decode(p, cfg, x, cache):
    """Single-token decode. x: [B, 1, d_model]."""
    gate = gelu(x[:, 0] @ p["w_gelu"])
    rec_in = x[:, 0] @ p["w_rec"]
    conv_buf = jnp.concatenate([cache["conv"], rec_in[:, None]], axis=1)
    rec = jnp.sum(conv_buf * p["conv_w"][None], axis=1) + p["conv_b"][None]
    y_rec, h = rg_lru_step(p, rec, cache["h"])
    y = ((gate * y_rec) @ p["w_out"])[:, None]
    return y, {"h": h, "conv": conv_buf[:, 1:]}
