"""Top-level model API: build_model(cfg) -> Model(init/loss/prefill/decode).

Every assigned architecture is served through this one API; the launcher,
trainer, server, benchmarks and dry-run all consume Model objects.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn as ffn_mod
from . import rglru, ssm, stack
from .common import (apply_norm, dense_init, embed_tokens, init_embedding,
                     init_norm, maybe_scan, sinusoidal_pos_emb)
from .config import ModelConfig

NEG_INF = -1e30


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable          # (params, batch, key|None) -> (loss, metrics)
    forward_hidden: Callable
    prefill: Callable       # (params, batch, max_len, key|None)
                            #   -> (cache, hidden, stats)
    decode: Callable        # (params, tokens, cache, t) -> (logits, cache)
    init_cache: Callable    # (batch, max_len) -> cache pytree


# ------------------------------------------------------------------ loss
def chunked_xent(hidden, head, labels, cfg):
    """Sequence-chunked vocab-masked cross entropy.

    hidden: [B, S, d]; head: [d, Vp]; labels: [B, S] int32 (-1 = ignore).
    Keeps the [B, chunk, Vp] logits buffer bounded so 256k vocabs fit.
    """
    b, s, d = hidden.shape
    vp = head.shape[-1]
    chunk = attn.pick_chunk(s, cfg.logits_chunk)
    nc = s // chunk
    vocab_ok = (jnp.arange(vp) < cfg.vocab_size)

    def step(carry, inp):
        tot, cnt = carry
        h_c, y_c = inp                                     # [B,c,d], [B,c]
        logits = jnp.einsum("bcd,dv->bcv", h_c.astype(jnp.float32),
                            head.astype(jnp.float32))
        logits = jnp.where(vocab_ok[None, None], logits, NEG_INF)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        mask = (y_c >= 0).astype(jnp.float32)
        tot += jnp.sum((lse - ll) * mask)
        cnt += jnp.sum(mask)
        return (tot, cnt), None

    hs = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    ys = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    (tot, cnt), _ = maybe_scan(jax.checkpoint(step),
                               (jnp.zeros(()), jnp.zeros(())),
                               (hs, ys), cfg.unroll_inner)
    return tot / jnp.maximum(cnt, 1.0)


def _head(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]


def _logits(params, cfg, hidden):
    logits = jnp.einsum("...d,dv->...v", hidden.astype(jnp.float32),
                        _head(params, cfg).astype(jnp.float32))
    vp = logits.shape[-1]
    return jnp.where(jnp.arange(vp) < cfg.vocab_size, logits, NEG_INF)


# ==================================================== decoder-only LM ====
def _init_lm(key, cfg):
    ks = jax.random.split(key, 4)
    kind = stack.layer_kind(cfg)
    params = {"embed": init_embedding(ks[0], cfg),
              "final_norm": init_norm(cfg)}
    if cfg.family == "hybrid":
        params["layers"] = stack.init_hybrid(ks[1], cfg)
    else:
        params["layers"] = stack.init_stack(ks[1], cfg, cfg.n_layers, kind)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.padded_vocab,
                                       cfg.jnp_dtype)
    if cfg.family == "vlm":
        params["patch_proj"] = dense_init(ks[3], cfg.d_model, cfg.d_model,
                                          cfg.jnp_dtype)
    return params


def _lm_embed(params, cfg, batch):
    x = embed_tokens(params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "patches" in batch:
        px = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([px, x], axis=1)
    if cfg.add_sinusoidal_pos:
        pe = sinusoidal_pos_emb(x.shape[1], cfg.d_model, x.dtype)
        if "pos_offset" in batch:
            # left-padded rows: embedding index counts from the first real
            # token (pad rows clip to index 0; they are masked downstream)
            idx = jnp.clip(jnp.arange(x.shape[1])[None]
                           - batch["pos_offset"][:, None].astype(jnp.int32),
                           0, None)
            x = x + pe[idx]
        else:
            x = x + pe[None]
    return x


def _lm_hidden(params, cfg, batch, mca_key):
    x = _lm_embed(params, cfg, batch)
    pos = jnp.arange(x.shape[1])[None]
    if cfg.family == "hybrid":
        x, aux, stats = stack.hybrid_forward(params["layers"], cfg, x,
                                             pos=pos, mca_key=mca_key)
    else:
        x, aux, stats = stack.stack_forward(
            params["layers"], cfg, x, pos=pos, mca_key=mca_key,
            kind=stack.layer_kind(cfg))
    x = apply_norm(params["final_norm"], cfg, x)
    return x, aux, stats


def _lm_loss(params, cfg, batch, mca_key=None):
    hidden, aux, stats = _lm_hidden(params, cfg, batch, mca_key)
    if cfg.family == "vlm" and "patches" in batch:
        hidden = hidden[:, batch["patches"].shape[1]:]
    loss = chunked_xent(hidden, _head(params, cfg), batch["labels"], cfg)
    metrics = {"loss": loss, "aux_loss": aux,
               "mca_exact_flops": stats["exact_flops"],
               "mca_flops": stats["mca_flops"],
               "mca_tier_hist": stats["tier_hist"]}
    return loss + aux, metrics


# ----------------------------------------------------------- cache utils
def _pad_seq_cache(arr, slots: int):
    """arr: [B, S, ...] -> ([B, slots, ...], slot_pos [B, slots])."""
    b, s = arr.shape[0], arr.shape[1]
    if slots >= s:                                   # global cache
        pad = [(0, 0)] * arr.ndim
        pad[1] = (0, slots - s)
        out = jnp.pad(arr, pad)
        slot_pos = jnp.where(jnp.arange(slots) < s,
                             jnp.arange(slots), -1).astype(jnp.int32)
    else:                                            # rolling window cache
        tail = arr[:, s - slots:]
        pos = jnp.arange(s - slots, s)
        slot = pos % slots
        out = jnp.zeros((b, slots) + arr.shape[2:], arr.dtype
                        ).at[:, slot].set(tail)
        slot_pos = jnp.zeros((slots,), jnp.int32).at[slot].set(pos)
    # slot_pos is per-row so per-slot insertion can splice one request's
    # position state without touching its batch neighbours
    return out, jnp.broadcast_to(slot_pos[None], (b, slots))


def _gqa_prefill_cache(cfg, k, v, max_len, window):
    slots = window if window > 0 else max_len
    kc, slot_pos = _pad_seq_cache(k, slots)
    vc, _ = _pad_seq_cache(v, slots)
    return {"k": kc, "v": vc, "slot_pos": slot_pos}


def cache_insert_slot(cache, new, slot):
    """Splice a batch-1 prefill cache into row ``slot`` of a live cache.

    ``cache`` is a batched LM decode cache (`{"layers": ..., "pos_off":
    [B]}` with every layer leaf scan-stacked `[L, 1-or-B, ...]`, batch on
    axis 1); ``new`` is the same structure from a batch=1 prefill at the
    same ``max_len``.  ``slot`` may be a traced int32 — the splice is a
    ``dynamic_update_slice`` per leaf, so occupied rows keep decoding
    undisturbed while the freed row admits the next request (per-slot
    continuous batching).
    """
    layers = jax.tree.map(
        lambda c, n: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), slot, axis=1),
        cache["layers"], new["layers"])
    out = {"layers": layers}
    if "pos_off" in cache:
        off = new.get("pos_off")
        if off is None:
            off = jnp.zeros((1,), jnp.int32)
        out["pos_off"] = jax.lax.dynamic_update_slice_in_dim(
            cache["pos_off"], off.astype(jnp.int32), slot, axis=0)
    return out


# -------------------------------------------------- LM prefill / decode
def _lm_prefill(params, cfg, batch, max_len, mca_key=None):
    """Run the full prompt, return (cache, last_hidden, stats).

    batch may carry "pos_offset" [B] int32 left-padding amounts (number of
    pad tokens at the front of each row). Offsets shift RoPE/positions to
    count from the first real token and mask padding keys everywhere, so a
    left-padded row generates exactly as it would alone.
    """
    x = _lm_embed(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    kind = stack.layer_kind(cfg)

    off = batch.get("pos_offset")
    if off is None:
        pos = jnp.arange(s)[None]
        kv_valid = None
        off_arr = jnp.zeros((b,), jnp.int32)
    else:
        if kind == "ssm" or cfg.family in ("hybrid", "vlm"):
            raise NotImplementedError(
                f"pos_offset prefill is not supported for {cfg.family!r} "
                "models (recurrent state has no padding mask)")
        off_arr = off.astype(jnp.int32)
        pos = jnp.arange(s)[None] - off_arr[:, None]
        kv_valid = jnp.arange(s)[None] >= off_arr[:, None]

    if cfg.family == "hybrid":
        cache, hid, stats = _hybrid_prefill(params, cfg, x, pos, max_len,
                                            mca_key)
        return cache, hid, stats

    def body(carry, inp):
        xx, stats = carry
        p_l, idx = inp
        key_l = None if mca_key is None else jax.random.fold_in(mca_key, idx)
        h = apply_norm(p_l["ln1"], cfg, xx)
        if kind == "ssm":
            y, state, conv_tail = ssm.mamba2_forward(p_l["mixer"], cfg, h,
                                                     return_state=True)
            xx = xx + y
            cache_l = {"state": state, "conv": conv_tail}
        elif cfg.attn_type == "mla":
            y, (ckv, kr), st, _ = attn.mla_attention(
                p_l["mixer"], cfg, h, pos=pos, mca_key=key_l,
                return_cache=True, kv_valid=kv_valid)
            stats = stack._add_stats(stats, st)
            xx = xx + y
            ckv_p, _ = _pad_seq_cache(ckv, max_len)
            kr_p, _ = _pad_seq_cache(kr, max_len)
            cache_l = {"ckv": ckv_p, "kr": kr_p}
        else:
            y, (k, v), st, _ = attn.gqa_attention(
                p_l["mixer"], cfg, h, pos=pos, mca_key=key_l,
                return_kv=True, kv_valid=kv_valid)
            stats = stack._add_stats(stats, st)
            xx = xx + y
            cache_l = _gqa_prefill_cache(cfg, k, v, max_len, cfg.window)
        if kind != "ssm":
            h = apply_norm(p_l["ln2"], cfg, xx)
            if kind == "attn_moe":
                y, _, st = ffn_mod.moe_ffn(p_l["ffn"], cfg, h,
                                           mca_key=key_l)
                stats = stack._add_stats(stats, st)
            else:
                y = ffn_mod.ffn(p_l["ffn"], cfg, h)
            xx = xx + y
        return (xx, stats), cache_l

    (x, stats), caches = maybe_scan(
        body, (x, stack._zero_carry_stats(cfg)),
        (params["layers"], jnp.arange(cfg.n_layers)),
        cfg.unroll_layers)
    x = apply_norm(params["final_norm"], cfg, x)
    return {"layers": caches, "pos_off": off_arr}, x, stats


def _decode_layer(p_l, cfg, xx, cache_l, t, kind, pos_off=None):
    h = apply_norm(p_l["ln1"], cfg, xx)
    if kind == "ssm":
        y, cache_l = ssm.mamba2_decode(p_l["mixer"], cfg, h, cache_l)
        return xx + y, cache_l
    if kind == "rec_ffn":
        y, cache_l = rglru.recurrent_decode(p_l["mixer"], cfg, h, cache_l)
        xx = xx + y
    elif cfg.attn_type == "mla":
        y, cache_l, _ = attn.mla_decode(p_l["mixer"], cfg, h, cache_l, t=t,
                                        pos_off=pos_off)
        xx = xx + y
    else:
        y, cache_l, _ = attn.gqa_decode(p_l["mixer"], cfg, h, cache_l, t=t,
                                        pos_off=pos_off)
        xx = xx + y
    h = apply_norm(p_l["ln2"], cfg, xx)
    if kind == "attn_moe":
        y, _, _ = ffn_mod.moe_ffn(p_l["ffn"], cfg, h)
    else:
        y = ffn_mod.ffn(p_l["ffn"], cfg, h)
    return xx + y, cache_l


def _lm_decode(params, cfg, tokens, cache, t):
    """tokens: [B, 1]; t: scalar int32. Returns (logits, cache)."""
    x = embed_tokens(params["embed"], tokens)
    kind = stack.layer_kind(cfg)
    if cfg.family == "hybrid":
        return _hybrid_decode(params, cfg, x, cache, t)
    pos_off = cache.get("pos_off")

    def body(xx, inp):
        p_l, cache_l = inp
        xx, new_cache = _decode_layer(p_l, cfg, xx, cache_l, t, kind,
                                      pos_off=pos_off)
        return xx, new_cache

    x, new_caches = maybe_scan(body, x, (params["layers"],
                                         cache["layers"]),
                               cfg.unroll_layers)
    x = apply_norm(params["final_norm"], cfg, x)
    new = {"layers": new_caches}
    if pos_off is not None:
        new["pos_off"] = pos_off
    return _logits(params, cfg, x), new


def _lm_init_cache(cfg, batch, max_len):
    kind = stack.layer_kind(cfg)
    dt = cfg.jnp_dtype

    def one():
        if kind == "ssm":
            return ssm.init_mamba2_cache(cfg, batch, dt)
        if cfg.attn_type == "mla":
            return attn.init_mla_cache(cfg, batch, max_len, dt)
        return attn.init_gqa_cache(cfg, batch, max_len, dt)

    caches = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[one() for _ in range(cfg.n_layers)])
    return {"layers": caches, "pos_off": jnp.zeros((batch,), jnp.int32)}


# ------------------------------------------------------- hybrid variants
def _hybrid_prefill(params, cfg, x, pos, max_len, mca_key):
    n_groups, pat, rem = stack.hybrid_layout(cfg)

    def make_cache(p_l, xx, stats, kind, key_l):
        h = apply_norm(p_l["ln1"], cfg, xx)
        if kind == "rec_ffn":
            y, conv_tail, h_fin = rglru.recurrent_block_with_state(
                p_l["mixer"], cfg, h)
            xx = xx + y
            cache_l = {"h": h_fin, "conv": conv_tail}
        else:
            y, (k, v), st, _ = attn.gqa_attention(
                p_l["mixer"], cfg, h, pos=pos, mca_key=key_l,
                window=cfg.window, return_kv=True)
            stats = stack._add_stats(stats, st)
            xx = xx + y
            cache_l = _gqa_prefill_cache(cfg, k, v, max_len, cfg.window)
        h = apply_norm(p_l["ln2"], cfg, xx)
        xx = xx + ffn_mod.ffn(p_l["ffn"], cfg, h)
        return xx, stats, cache_l

    def body(carry, inp):
        xx, stats = carry
        gp, gidx = inp
        caches = {}
        for i, kind in enumerate(pat):
            key_l = None if mca_key is None else jax.random.fold_in(
                mca_key, gidx * len(pat) + i)
            xx, stats, caches[f"pos{i}"] = make_cache(gp[f"pos{i}"], xx,
                                                      stats, kind, key_l)
        return (xx, stats), caches

    (x, stats), gcaches = maybe_scan(
        body, (x, stack._zero_carry_stats(cfg)),
        (params["layers"]["groups"], jnp.arange(n_groups)),
        cfg.unroll_layers)
    rem_caches = []
    for i, kind in enumerate(rem):
        key_l = None if mca_key is None else jax.random.fold_in(
            mca_key, n_groups * len(pat) + i)
        x, stats, c = make_cache(params["layers"]["rem"][i], x, stats,
                                 kind, key_l)
        rem_caches.append(c)
    x = apply_norm(params["final_norm"], cfg, x)
    return {"groups": gcaches, "rem": rem_caches}, x, stats


def _hybrid_decode(params, cfg, x, cache, t):
    n_groups, pat, rem = stack.hybrid_layout(cfg)

    def body(xx, inp):
        gp, gc = inp
        new_c = {}
        for i, kind in enumerate(pat):
            xx, new_c[f"pos{i}"] = _decode_layer(gp[f"pos{i}"], cfg, xx,
                                                 gc[f"pos{i}"], t, kind)
        return xx, new_c

    x, gcaches = maybe_scan(body, x, (params["layers"]["groups"],
                                      cache["groups"]),
                            cfg.unroll_layers)
    rem_caches = []
    for i, kind in enumerate(rem):
        x, c = _decode_layer(params["layers"]["rem"][i], cfg, x,
                             cache["rem"][i], t, kind)
        rem_caches.append(c)
    x = apply_norm(params["final_norm"], cfg, x)
    return _logits(params, cfg, x), {"groups": gcaches, "rem": rem_caches}


def _hybrid_init_cache(cfg, batch, max_len):
    n_groups, pat, rem = stack.hybrid_layout(cfg)
    dt = cfg.jnp_dtype

    def one(kind):
        if kind == "rec_ffn":
            return rglru.init_recurrent_cache(cfg, batch, dt)
        return attn.init_gqa_cache(cfg, batch, max_len, dt)

    groups = {
        f"pos{i}": jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[one(kind) for _ in range(n_groups)])
        for i, kind in enumerate(pat)}
    return {"groups": groups, "rem": [one(k) for k in rem]}


# ====================================================== encoder-decoder ==
def _init_encdec(key, cfg):
    ks = jax.random.split(key, 5)
    params = {
        "embed": init_embedding(ks[0], cfg),
        "enc_layers": stack.init_stack(ks[1], cfg, cfg.n_encoder_layers,
                                       "attn_ffn"),
        "enc_norm": init_norm(cfg),
        "dec_layers": stack.init_stack(ks[2], cfg, cfg.n_layers,
                                       "dec_attn_ffn"),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], cfg.d_model, cfg.padded_vocab,
                                       cfg.jnp_dtype)
    return params


def _encode(params, cfg, frames, mca_key):
    x = frames.astype(cfg.jnp_dtype)
    x = x + sinusoidal_pos_emb(x.shape[1], cfg.d_model,
                               x.dtype)[None]
    pos = jnp.arange(x.shape[1])[None]
    x, _, stats = stack.stack_forward(
        params["enc_layers"], cfg, x, pos=pos, mca_key=mca_key,
        kind="attn_ffn", causal=False, window=0)
    return apply_norm(params["enc_norm"], cfg, x), stats


def _encdec_hidden(params, cfg, batch, mca_key):
    enc_key = None if mca_key is None else jax.random.fold_in(mca_key, 101)
    enc_out, enc_stats = _encode(params, cfg, batch["frames"], enc_key)
    x = embed_tokens(params["embed"], batch["tokens"])
    x = x + sinusoidal_pos_emb(x.shape[1], cfg.d_model, x.dtype)[None]
    pos = jnp.arange(x.shape[1])[None]
    x, aux, stats = stack.stack_forward(
        params["dec_layers"], cfg, x, pos=pos, mca_key=mca_key,
        kind="dec_attn_ffn", enc_out=enc_out, causal=True, window=0)
    stats = {k: stats[k] + enc_stats[k] for k in stats}
    x = apply_norm(params["final_norm"], cfg, x)
    return x, aux, stats, enc_out


def _encdec_loss(params, cfg, batch, mca_key=None):
    hidden, aux, stats, _ = _encdec_hidden(params, cfg, batch, mca_key)
    loss = chunked_xent(hidden, _head(params, cfg), batch["labels"], cfg)
    return loss + aux, {"loss": loss, "aux_loss": aux,
                        "mca_exact_flops": stats["exact_flops"],
                        "mca_flops": stats["mca_flops"]}


def _encdec_prefill(params, cfg, batch, max_len, mca_key=None):
    if batch.get("pos_offset") is not None:
        raise NotImplementedError(
            "pos_offset prefill is not supported for encoder-decoder models")
    enc_key = None if mca_key is None else jax.random.fold_in(mca_key, 101)
    enc_out, _ = _encode(params, cfg, batch["frames"], enc_key)
    x = embed_tokens(params["embed"], batch["tokens"])
    x = x + sinusoidal_pos_emb(x.shape[1], cfg.d_model, x.dtype)[None]
    pos = jnp.arange(x.shape[1])[None]

    def body(carry, inp):
        xx, stats = carry
        p_l, idx = inp
        key_l = None if mca_key is None else jax.random.fold_in(mca_key, idx)
        h = apply_norm(p_l["ln1"], cfg, xx)
        y, (k, v), st, _ = attn.gqa_attention(p_l["mixer"], cfg, h, pos=pos,
                                              mca_key=key_l, return_kv=True)
        stats = stack._add_stats(stats, st)
        xx = xx + y
        self_cache = _gqa_prefill_cache(cfg, k, v, max_len, 0)
        h = apply_norm(p_l["ln_x"], cfg, xx)
        y, (ck, cv), st, _ = attn.gqa_attention(
            p_l["cross"], cfg, h, pos=pos, mca_key=key_l, causal=False,
            window=0, kv_x=enc_out, return_kv=True)
        stats = stack._add_stats(stats, st)
        xx = xx + y
        h = apply_norm(p_l["ln2"], cfg, xx)
        xx = xx + ffn_mod.ffn(p_l["ffn"], cfg, h)
        return (xx, stats), {"self": self_cache, "cross_k": ck,
                             "cross_v": cv}

    (x, stats), caches = maybe_scan(
        body, (x, stack._zero_carry_stats(cfg)),
        (params["dec_layers"], jnp.arange(cfg.n_layers)),
        cfg.unroll_layers)
    x = apply_norm(params["final_norm"], cfg, x)
    return {"layers": caches}, x, stats


def _cross_decode(p, cfg, x, ck, cv):
    """One-query cross attention against cached encoder K/V."""
    b = x.shape[0]
    hkv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    dh = cfg.d_head
    q = (x @ p["wq"]).reshape(b, 1, hkv, g, dh)
    s = jnp.einsum("bqhgd,bshd->bhgqs", q, ck,
                   preferred_element_type=jnp.float32) * dh ** -0.5
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", a.astype(cv.dtype), cv)
    return out.reshape(b, 1, cfg.n_heads * dh) @ p["wo"]


def _encdec_decode(params, cfg, tokens, cache, t):
    x = embed_tokens(params["embed"], tokens)
    pe = sinusoidal_pos_emb(cache["layers"]["self"]["k"].shape[2],
                            cfg.d_model, x.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(pe, t, 1)[None]

    def body(xx, inp):
        p_l, cache_l = inp
        h = apply_norm(p_l["ln1"], cfg, xx)
        y, new_self, _ = attn.gqa_decode(p_l["mixer"], cfg, h,
                                         cache_l["self"], t=t)
        xx = xx + y
        h = apply_norm(p_l["ln_x"], cfg, xx)
        xx = xx + _cross_decode(p_l["cross"], cfg, h, cache_l["cross_k"],
                                cache_l["cross_v"])
        h = apply_norm(p_l["ln2"], cfg, xx)
        xx = xx + ffn_mod.ffn(p_l["ffn"], cfg, h)
        return xx, {"self": new_self, "cross_k": cache_l["cross_k"],
                    "cross_v": cache_l["cross_v"]}

    x, new_caches = maybe_scan(body, x, (params["dec_layers"],
                                         cache["layers"]),
                               cfg.unroll_layers)
    x = apply_norm(params["final_norm"], cfg, x)
    return _logits(params, cfg, x), {"layers": new_caches}


def _encdec_init_cache(cfg, batch, max_len):
    dt = cfg.jnp_dtype

    def one():
        return {
            "self": attn.init_gqa_cache(cfg, batch, max_len, dt),
            "cross_k": jnp.zeros((batch, cfg.encoder_len, cfg.n_kv_heads,
                                  cfg.d_head), dt),
            "cross_v": jnp.zeros((batch, cfg.encoder_len, cfg.n_kv_heads,
                                  cfg.d_head), dt),
        }

    caches = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[one() for _ in range(cfg.n_layers)])
    return {"layers": caches}


# ================================================================ factory
def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encoder_decoder:
        return Model(
            cfg=cfg,
            init=lambda key: _init_encdec(key, cfg),
            loss=lambda p, b, key=None: _encdec_loss(p, cfg, b, key),
            forward_hidden=lambda p, b, key=None: _encdec_hidden(
                p, cfg, b, key)[:3],
            prefill=lambda p, b, max_len, key=None: _encdec_prefill(
                p, cfg, b, max_len, key),
            decode=lambda p, tok, cache, t: _encdec_decode(
                p, cfg, tok, cache, t),
            init_cache=lambda batch, max_len: _encdec_init_cache(
                cfg, batch, max_len),
        )
    init_cache = (_hybrid_init_cache if cfg.family == "hybrid"
                  else _lm_init_cache)
    return Model(
        cfg=cfg,
        init=lambda key: _init_lm(key, cfg),
        loss=lambda p, b, key=None: _lm_loss(p, cfg, b, key),
        forward_hidden=lambda p, b, key=None: _lm_hidden(p, cfg, b, key),
        prefill=lambda p, b, max_len, key=None: _lm_prefill(
            p, cfg, b, max_len, key),
        decode=lambda p, tok, cache, t: _lm_decode(p, cfg, tok, cache, t),
        init_cache=lambda batch, max_len: init_cache(cfg, batch, max_len),
    )
