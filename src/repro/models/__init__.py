"""Model zoo: layers + stacks for all assigned architectures."""
from .api import Model, build_model
from .config import ModelConfig, reduced

__all__ = ["Model", "ModelConfig", "build_model", "reduced"]
