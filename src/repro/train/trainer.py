"""Trainer: step loop + fault tolerance (checkpoint/restart, step watchdog,
deterministic data replay) designed for preemptible fleets.

Fault-tolerance model (1000+ nodes posture):
  * checkpoints are atomic + async + checksummed; restart restores the
    latest *valid* step (corrupt/torn checkpoints are skipped) and replays
    the data stream deterministically from there;
  * every step's loss / grad-norm is finite-checked: a NaN/Inf step is
    *skipped* (params and optimizer state keep their pre-step values,
    ``train.skipped_steps`` counts it) instead of training on garbage;
    after ``max_bad_steps`` consecutive bad steps the trainer rolls back
    to the last valid checkpoint (``resilience.train.rollbacks``).
    Because data replay is deterministic, a rollback replays the same
    batches with the same params — so rollbacks are bounded by
    ``max_rollbacks``; past that the trainer aborts with
    :class:`TrainingDivergedError` instead of livelocking.  The skip /
    rollback path reuses pre-step buffers, so it requires a
    *non-donating* train_step — ``Trainer(..., step_donates=True)`` with
    ``finite_checks`` on is rejected at init (donated buffers are freed
    on device and the first skipped step would crash with
    "Array has been deleted");
  * a watchdog thread flags steps exceeding ``watchdog_s`` (straggler /
    hung-collective detection) and escalates from log-only to an actual
    recovery callback after ``watchdog_escalate_after`` firings;
  * failed async checkpoint writes no longer die silently: the exception
    surfaces on the next save/wait, is counted
    (``resilience.train.ckpt_failures``) and training continues —
    availability over durability, with the gap visible in metrics;
  * elastic restart: restore() accepts new-mesh shardings, so a job can
    come back on a different host count (see checkpoint/checkpoint.py).
"""
from __future__ import annotations

import dataclasses
import logging
import math
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro import obs, resilience
from repro.checkpoint import checkpoint as ckpt
from repro.optim import adamw

log = logging.getLogger("repro.trainer")


class TrainingDivergedError(RuntimeError):
    """Raised when rollbacks keep hitting the same non-finite steps.

    Deterministic data replay means a rollback re-runs the exact batches
    with the exact params that just diverged; after ``max_rollbacks``
    attempts the run cannot make progress and must be aborted (a human /
    coordinator decides: lower the LR, change the data window, ...)."""


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    watchdog_s: float = 300.0
    keep: int = 3
    metrics_path: Optional[str] = None   # JSONL sink for per-step records
    finite_checks: bool = True           # skip NaN/Inf steps
    max_bad_steps: int = 3               # consecutive bad steps -> rollback
    max_rollbacks: int = 2               # rollbacks before aborting the run
    watchdog_escalate_after: int = 2     # firings before recovery_cb runs
    recovery_cb: Optional[Callable] = None   # called on watchdog escalation


class Watchdog:
    """Flags steps that exceed the deadline (straggler mitigation hook).

    Escalation ladder: every firing logs + counts
    (``resilience.train.watchdog_fired``); from ``escalate_after`` firings
    on, ``on_escalate(step)`` runs too (``resilience.train.
    watchdog_escalations``) — on a real fleet that is the coordinator's
    preempt/restart path, in tests a recovery callback."""

    def __init__(self, deadline_s: float, escalate_after: int = 2,
                 on_escalate: Optional[Callable] = None):
        self.deadline = deadline_s
        self.escalate_after = escalate_after
        self.on_escalate = on_escalate
        self.fired = 0
        self.escalations = 0
        self._timer: Optional[threading.Timer] = None

    def arm(self, step: int):
        self.disarm()
        # capture the ambient registry: the timer fires on its own thread
        reg = obs.get_registry()
        self._timer = threading.Timer(self.deadline, self._fire,
                                      args=(step, reg))
        self._timer.daemon = True
        self._timer.start()

    def _fire(self, step: int, reg):
        self.fired += 1
        reg.counter("resilience.train.watchdog_fired").inc()
        log.warning("watchdog: step %d exceeded %.0fs — straggler or hung "
                    "collective; coordinator should preempt/restart",
                    step, self.deadline)
        if self.fired >= self.escalate_after and self.on_escalate:
            self.escalations += 1
            reg.counter("resilience.train.watchdog_escalations").inc()
            try:
                self.on_escalate(step)
            except Exception:                              # noqa: BLE001
                log.exception("watchdog recovery callback failed")

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None


class Trainer:
    def __init__(self, model, opt_cfg: adamw.AdamWConfig, data,
                 train_step: Callable, cfg: TrainerConfig,
                 init_params: Optional[Any] = None,
                 step_donates: bool = False):
        if step_donates and cfg.finite_checks:
            raise ValueError(
                "finite_checks requires a non-donating train_step: the "
                "skip/rollback path reuses pre-step params/opt_state, "
                "which donation frees on device ('Array has been "
                "deleted' on the first skipped step). Build the step "
                "without donation (jit_train_step(donate=False) / no "
                "donate_argnums) or set TrainerConfig.finite_checks="
                "False.")
        self.model = model
        self.opt_cfg = opt_cfg
        self.data = data
        self.train_step = train_step
        self.cfg = cfg
        self.watchdog = Watchdog(cfg.watchdog_s, cfg.watchdog_escalate_after,
                                 cfg.recovery_cb)
        self.checkpointer = (ckpt.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep)
                             if cfg.ckpt_dir else None)
        self.sink = (obs.JsonlSink(cfg.metrics_path)
                     if cfg.metrics_path else None)
        self.history: list = []
        self.ckpt_errors = 0
        self.rollbacks = 0
        self._bad_streak = 0

        self.params = (init_params if init_params is not None
                       else model.init(jax.random.PRNGKey(0)))
        self.opt_state = adamw.init_state(self.params)
        self.start_step = 0
        if cfg.ckpt_dir:
            like = {"params": self.params, "opt": self.opt_state}
            step, state = ckpt.restore_latest_valid(cfg.ckpt_dir, like)
            if step is not None:
                self.params = state["params"]
                self.opt_state = state["opt"]
                self.start_step = step
                log.info("restored checkpoint at step %d", step)

    def _record_step(self, step: int, loss: float, dt: float, metrics,
                     status: str = "ok"):
        """Per-step MCA stats -> obs registry (+ optional JSONL record)."""
        reg = obs.get_registry()
        reg.counter("train.steps").inc()
        reg.histogram("train.step_seconds").observe(dt)
        span = getattr(self, "_last_step_span", None)
        if span is not None:
            obs.record_span("train.step", span[0], span[1], cat="train",
                            track="trainer",
                            args={"step": step, "status": status,
                                  "loss": loss if math.isfinite(loss)
                                  else str(loss)})
        record: Dict[str, Any] = {"step": step, "loss": loss, "dt": dt,
                                  "status": status}
        if "mca_exact_flops" in metrics:
            exact = float(metrics["mca_exact_flops"])
            mca = float(metrics["mca_flops"])
            fr = exact / max(mca, 1.0)
            reg.gauge("train.flops_reduction").set(fr)
            record["flops_reduction"] = fr
        hist = metrics.get("mca_tier_hist")
        if hist is not None:
            hist = np.asarray(hist, np.float64)
            for i, c in enumerate(hist):
                reg.counter(f"train.tier_occupancy.t{i}").inc(float(c))
            record["tier_hist"] = hist.tolist()
        if self.sink:
            self.sink.write("train_step", **record)
        return record

    # ----------------------------------------------------- fault handling
    def _step_is_bad(self, loss: float, metrics) -> bool:
        if not self.cfg.finite_checks:
            return False
        if not math.isfinite(loss):
            return True
        gnorm = metrics.get("grad_norm")
        return gnorm is not None and not resilience.is_finite(
            float(np.asarray(gnorm)))

    def _rollback(self, step: int) -> int:
        """Restore params/opt from the last valid checkpoint; returns the
        step to resume from (``step`` unchanged if nothing to restore)."""
        reg = obs.get_registry()
        if not self.checkpointer:
            log.error("no checkpoint dir: cannot roll back at step %d",
                      step)
            return step
        like = {"params": self.params, "opt": self.opt_state}
        ck_step, state = ckpt.restore_latest_valid(self.cfg.ckpt_dir, like)
        if ck_step is None:
            log.error("rollback requested at step %d but no valid "
                      "checkpoint exists; continuing with current state",
                      step)
            return step
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.rollbacks += 1
        reg.counter("resilience.train.rollbacks").inc()
        log.warning("rolled back from step %d to checkpoint step %d after "
                    "%d consecutive bad steps (rollback %d/%d)", step,
                    ck_step, self._bad_streak, self.rollbacks,
                    self.cfg.max_rollbacks)
        return ck_step

    def _save(self, step: int) -> None:
        """Async checkpoint; a failed previous write surfaces here and is
        absorbed (counted + logged) so training keeps running."""
        try:
            self.checkpointer.save(
                step, {"params": self.params, "opt": self.opt_state})
        except Exception:                                  # noqa: BLE001
            self.ckpt_errors += 1
            obs.get_registry().counter(
                "resilience.train.ckpt_failures").inc()
            log.exception("checkpoint write failed at step %d (training "
                          "continues; durability gap until next save)",
                          step)

    def run(self) -> Dict[str, Any]:
        reg = obs.get_registry()
        step = self.start_step
        t_start = time.time()
        while step < self.cfg.total_steps:
            batch = self.data.batch(step)
            batch = jax.tree.map(jax.numpy.asarray, batch)
            self.watchdog.arm(step)
            t0 = time.time()
            tp0 = time.perf_counter()
            resilience.inject("train.step")
            with obs.trace("trainer.step"):
                new_params, new_opt, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                loss = float(metrics["total_loss"])   # sync point
            tp1 = time.perf_counter()
            loss = resilience.inject("train.loss", loss)
            if loss is None:
                loss = float("nan")
            self.watchdog.disarm()
            dt = time.time() - t0
            self._last_step_span = (tp0, tp1)
            if self._step_is_bad(loss, metrics):
                self._bad_streak += 1
                reg.counter("train.skipped_steps").inc()
                log.warning("step %d: non-finite loss/grads (loss=%s) — "
                            "skipping update (%d consecutive)",
                            step + 1, loss, self._bad_streak)
                if self._bad_streak >= self.cfg.max_bad_steps:
                    if self.rollbacks >= self.cfg.max_rollbacks:
                        raise TrainingDivergedError(
                            f"step {step + 1}: {self._bad_streak} "
                            f"consecutive non-finite steps after "
                            f"{self.rollbacks} rollbacks — deterministic "
                            f"replay would reproduce the same divergence; "
                            f"aborting instead of livelocking")
                    step = self._rollback(step + 1)
                    self._bad_streak = 0
                    continue
                # skip: keep pre-step params/opt, advance past the batch
                # (non-donating train_step — enforced at init)
                step += 1
                self.history.append(self._record_step(
                    step, loss, dt, metrics, status="skipped"))
                continue
            self._bad_streak = 0
            self.params, self.opt_state = new_params, new_opt
            step += 1
            record = self._record_step(step, loss, dt, metrics)
            self.history.append(record)
            if step % self.cfg.log_every == 0 or step == 1:
                fr = record.get("flops_reduction")
                log.info("step %d loss %.4f (%.2fs/step)%s", step, loss, dt,
                         "" if fr is None else f" flops_reduction {fr:.2f}x")
            if self.checkpointer and step % self.cfg.ckpt_every == 0:
                self._save(step)
        if self.checkpointer:
            self._save(self.cfg.total_steps)
            try:
                self.checkpointer.wait()
            except Exception:                              # noqa: BLE001
                self.ckpt_errors += 1
                reg.counter("resilience.train.ckpt_failures").inc()
                log.exception("final checkpoint write failed")
        if self.sink:
            self.sink.write_snapshot()
        return {"steps": step - self.start_step,
                "wall_s": time.time() - t_start,
                "final_loss": self.history[-1]["loss"] if self.history
                else float("nan"),
                "watchdog_fired": self.watchdog.fired,
                "ckpt_errors": self.ckpt_errors,
                "history": self.history}
