"""Trainer: step loop + fault tolerance (checkpoint/restart, step watchdog,
deterministic data replay) designed for preemptible fleets.

Fault-tolerance model (1000+ nodes posture):
  * checkpoints are atomic + async; restart restores the latest step and
    replays the data stream deterministically from there;
  * a watchdog thread flags steps exceeding ``watchdog_s`` (straggler /
    hung-collective detection — on a real fleet this triggers the
    coordinator's restart path; here it logs and counts);
  * elastic restart: restore() accepts new-mesh shardings, so a job can
    come back on a different host count (see checkpoint/checkpoint.py).
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint import checkpoint as ckpt
from repro.optim import adamw

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    watchdog_s: float = 300.0
    keep: int = 3
    metrics_path: Optional[str] = None   # JSONL sink for per-step records


class Watchdog:
    """Flags steps that exceed the deadline (straggler mitigation hook)."""

    def __init__(self, deadline_s: float):
        self.deadline = deadline_s
        self.fired = 0
        self._timer: Optional[threading.Timer] = None

    def arm(self, step: int):
        self.disarm()
        self._timer = threading.Timer(self.deadline, self._fire, args=(step,))
        self._timer.daemon = True
        self._timer.start()

    def _fire(self, step: int):
        self.fired += 1
        log.warning("watchdog: step %d exceeded %.0fs — straggler or hung "
                    "collective; coordinator should preempt/restart",
                    step, self.deadline)

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None


class Trainer:
    def __init__(self, model, opt_cfg: adamw.AdamWConfig, data,
                 train_step: Callable, cfg: TrainerConfig,
                 init_params: Optional[Any] = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.data = data
        self.train_step = train_step
        self.cfg = cfg
        self.watchdog = Watchdog(cfg.watchdog_s)
        self.checkpointer = (ckpt.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep)
                             if cfg.ckpt_dir else None)
        self.sink = (obs.JsonlSink(cfg.metrics_path)
                     if cfg.metrics_path else None)
        self.history: list = []

        self.params = (init_params if init_params is not None
                       else model.init(jax.random.PRNGKey(0)))
        self.opt_state = adamw.init_state(self.params)
        self.start_step = 0
        if cfg.ckpt_dir:
            latest = ckpt.latest_step(cfg.ckpt_dir)
            if latest is not None:
                state = {"params": self.params, "opt": self.opt_state}
                state = ckpt.restore(cfg.ckpt_dir, latest, state)
                self.params = state["params"]
                self.opt_state = state["opt"]
                self.start_step = latest
                log.info("restored checkpoint at step %d", latest)

    def _record_step(self, step: int, loss: float, dt: float, metrics):
        """Per-step MCA stats -> obs registry (+ optional JSONL record)."""
        reg = obs.get_registry()
        reg.counter("train.steps").inc()
        reg.histogram("train.step_seconds").observe(dt)
        record: Dict[str, Any] = {"step": step, "loss": loss, "dt": dt}
        if "mca_exact_flops" in metrics:
            exact = float(metrics["mca_exact_flops"])
            mca = float(metrics["mca_flops"])
            fr = exact / max(mca, 1.0)
            reg.gauge("train.flops_reduction").set(fr)
            record["flops_reduction"] = fr
        hist = metrics.get("mca_tier_hist")
        if hist is not None:
            hist = np.asarray(hist, np.float64)
            for i, c in enumerate(hist):
                reg.counter(f"train.tier_occupancy.t{i}").inc(float(c))
            record["tier_hist"] = hist.tolist()
        if self.sink:
            self.sink.write("train_step", **record)
        return record

    def run(self) -> Dict[str, Any]:
        step = self.start_step
        t_start = time.time()
        while step < self.cfg.total_steps:
            batch = self.data.batch(step)
            batch = jax.tree.map(jax.numpy.asarray, batch)
            self.watchdog.arm(step)
            t0 = time.time()
            with obs.trace("trainer.step"):
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                loss = float(metrics["total_loss"])   # sync point
            self.watchdog.disarm()
            dt = time.time() - t0
            step += 1
            record = self._record_step(step, loss, dt, metrics)
            self.history.append(record)
            if step % self.cfg.log_every == 0 or step == 1:
                fr = record.get("flops_reduction")
                log.info("step %d loss %.4f (%.2fs/step)%s", step, loss, dt,
                         "" if fr is None else f" flops_reduction {fr:.2f}x")
            if self.checkpointer and step % self.cfg.ckpt_every == 0:
                self.checkpointer.save(
                    step, {"params": self.params, "opt": self.opt_state})
        if self.checkpointer:
            self.checkpointer.save(
                self.cfg.total_steps,
                {"params": self.params, "opt": self.opt_state})
            self.checkpointer.wait()
        if self.sink:
            self.sink.write_snapshot()
        return {"steps": step - self.start_step,
                "wall_s": time.time() - t_start,
                "final_loss": self.history[-1]["loss"] if self.history
                else float("nan"),
                "watchdog_fired": self.watchdog.fired,
                "history": self.history}
