from .step import (jit_train_step, make_decode_step, make_prefill_step,
                   make_train_step, train_step_shardings)
from .trainer import (Trainer, TrainerConfig,
                      TrainingDivergedError, Watchdog)
