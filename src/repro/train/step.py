"""Jitted, sharded train / eval steps."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models.api import Model
from repro.optim import adamw


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    n_micro: int = 1, seed: int = 0, with_mca: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_fn(p, b, k):
        return model.loss(p, b, k if with_mca else None)

    def train_step(params, opt_state, batch):
        key = jax.random.fold_in(jax.random.PRNGKey(seed),
                                 opt_state["count"])
        (loss, metrics), grads = adamw.accumulate_gradients(
            loss_fn, params, batch, n_micro, key)
        params, opt_state, gnorm = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["total_loss"] = loss
        return params, opt_state, metrics

    return train_step


def abstract_state(model: Model, key=None):
    """eval_shape'd (params, opt_state) — no allocation."""
    a_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    a_opt = jax.eval_shape(adamw.init_state, a_params)
    return a_params, a_opt


def train_step_shardings(mesh, model: Model, abstract_batch,
                         fsdp: bool = True):
    """(in_shardings, out_shardings) for jit(train_step).

    fsdp=True (default for training) additionally shards params/grads over
    the data axis (FSDP/ZeRO-3 style); XLA all-gathers each layer's weights
    on demand inside the scan. Inference shardings keep TP-only weights
    (per-token all-gathers would dominate decode latency).
    """
    a_params, a_opt = abstract_state(model)
    p_sh = shd.param_shardings(mesh, a_params, model.cfg)
    z_sh = shd.zero1_shardings(mesh, p_sh, a_params)
    if fsdp:
        p_sh = z_sh
    opt_sh = {"m": z_sh, "v": z_sh, "count": NamedSharding(mesh, P())}
    b_sh = shd.batch_shardings(mesh, abstract_batch)
    in_sh = (p_sh, opt_sh, b_sh)
    out_sh = (p_sh, opt_sh, None)
    return in_sh, out_sh


def jit_train_step(mesh, model: Model, opt_cfg, abstract_batch,
                   n_micro: int = 1, seed: int = 0, donate: bool = True):
    step = make_train_step(model, opt_cfg, n_micro, seed)
    in_sh, out_sh = train_step_shardings(mesh, model, abstract_batch)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 1) if donate else ())


# ------------------------------------------------------------- serving
def make_prefill_step(model: Model, max_len: int, with_mca: bool = True,
                      seed: int = 0):
    def prefill(params, batch):
        key = jax.random.PRNGKey(seed) if with_mca else None
        cache, hidden, _ = model.prefill(params, batch, max_len, key)
        from repro.models.api import _logits
        logits = _logits(params, model.cfg, hidden[:, -1:])
        return cache, logits
    return prefill


def make_decode_step(model: Model):
    def decode(params, tokens, cache, t):
        return model.decode(params, tokens, cache, t)
    return decode


def serve_step_shardings(mesh, model: Model, abstract_cache,
                         abstract_tokens):
    a_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = shd.param_shardings(mesh, a_params, model.cfg)
    c_sh = shd.cache_shardings(mesh, abstract_cache)
    t_sh = shd.batch_shardings(mesh, abstract_tokens)
    return p_sh, c_sh, t_sh
