from .engine import (ContinuousBatcher, Engine, Request, SlotBatcher,
                     SlotState)
