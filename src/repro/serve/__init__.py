from .engine import ContinuousBatcher, Engine, Request
