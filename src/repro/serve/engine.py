"""Serving: prefill + decode engine with a hardened continuous batcher.

The engine wraps Model.prefill/Model.decode into jitted, cache-donating
steps; ``ContinuousBatcher`` multiplexes requests onto fixed decode slots
(vLLM-style slot reuse at toy scale — enough to drive the serving example
and tests end-to-end).

Ragged prompts are LEFT-padded with ``pad_id`` and per-row ``pos_offset``
amounts are threaded through prefill/decode: padding keys are masked out
of attention and RoPE/positions count from the first real token, so a
short prompt batched with a long one generates exactly what it would
alone (MCA off; with MCA on, capacity routing couples rows of a batch by
design).

Robustness (see ROADMAP.md § Robustness):

* **Admission control** — ``submit`` validates prompt length against the
  KV-cache capacity (``len(prompt) + max_new <= max_len``) and a bounded
  queue; rejected requests get ``status="rejected"`` with a reason and a
  ``serve.rejected.<reason>`` counter instead of crashing a wave later.
  Waves are assembled capacity-aware: a wave runs at the max prompt
  length / max ``max_new`` over its members, so requests that would
  jointly overrun ``max_len`` are deferred to the next wave rather than
  batched into a guaranteed failure.
* **Deadlines** — a request carrying ``deadline_s`` that has not finished
  within that budget of submission is dropped with ``status="timeout"``.
* **Degradation ladder** — a wave that raises or produces non-finite
  logits is retried (with backoff) with MCA *disabled*: exact attention
  reconstructs what the Monte-Carlo estimator corrupted (requests finish
  ``degraded`` rather than ``failed``).  Only when the exact retry also
  fails is the wave marked ``failed`` — the batcher never crashes.
* Per-request terminal status: ``ok | degraded | timeout | rejected |
  failed`` (on ``Request.status`` and ``ContinuousBatcher.status``).

Serving metrics land in the ``repro.obs`` registry: ``serve.prefill_seconds``,
``serve.decode_step_seconds``, ``serve.generated_tokens``,
``serve.flops_reduction``, ``serve.tier_occupancy.t{i}``, per-wave
``serve.wave_seconds`` / ``serve.slot_utilization``, admission counters
``serve.rejected.*`` and recovery counters ``resilience.serve.*``.
Dummy padding slots in a partial wave are excluded from token and MCA
FLOPs accounting.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, resilience
from repro.models.api import Model, _logits

log = logging.getLogger("repro.serve")

# terminal request statuses
OK, DEGRADED, TIMEOUT, REJECTED, FAILED = (
    "ok", "degraded", "timeout", "rejected", "failed")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 16
    deadline_s: Optional[float] = None    # wall budget from submit()
    out: Optional[List[int]] = None
    status: str = "queued"
    reason: Optional[str] = None          # set when rejected/failed
    submit_t: float = 0.0


class Engine:
    def __init__(self, model: Model, params, batch_size: int, max_len: int,
                 mca_enabled: bool = False, seed: int = 0, pad_id: int = 0):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.pad_id = pad_id
        self.mca_enabled = mca_enabled
        self.key = jax.random.PRNGKey(seed) if mca_enabled else None

        cfg = model.cfg

        def make_prefill(key):
            def prefill(params, batch_in):
                cache, hidden, stats = model.prefill(params, batch_in,
                                                     max_len, key)
                return cache, _logits(params, cfg, hidden[:, -1:]), stats
            return jax.jit(prefill)

        def decode(params, tok, cache, t):
            return model.decode(params, tok, cache, t)

        self._prefill = make_prefill(self.key)
        # exact-attention fallback path for the degradation ladder (same
        # trace as an MCA-off engine, so fallback output is token-identical)
        self._prefill_exact = (self._prefill if self.key is None
                               else make_prefill(None))
        self._decode = jax.jit(decode, donate_argnums=(2,))

    def _record_mca(self, stats, frac: float) -> None:
        """frac: fraction of batch rows that are real requests — dummy
        padding slots must not inflate MCA FLOPs accounting."""
        reg = obs.get_registry()
        exact = float(stats["exact_flops"]) * frac
        mca = float(stats["mca_flops"]) * frac
        reg.counter("serve.mca_exact_flops").inc(exact)
        reg.counter("serve.mca_flops").inc(mca)
        # no MCA accounting (disabled / exact-only sites) -> neutral 1x
        reg.gauge("serve.flops_reduction").set(
            exact / mca if mca > 0 else 1.0)
        hist = np.asarray(stats["tier_hist"])
        for i, c in enumerate(hist):
            reg.counter(f"serve.tier_occupancy.t{i}").inc(float(c) * frac)

    def generate(self, prompts: np.ndarray, max_new: int,
                 greedy: bool = True,
                 prompt_lens: Optional[np.ndarray] = None,
                 n_real: Optional[int] = None,
                 mca: bool = True,
                 check_finite: bool = True) -> np.ndarray:
        """prompts: [B, S] (left-padded if ragged). Returns [B, max_new]
        generated ids.  prompt_lens: optional [B] real prompt lengths —
        rows shorter than S get position offsets so left-padding is
        invisible to the model.  n_real: rows that are real requests (the
        rest are dummy padding slots, excluded from token/FLOPs metrics).
        mca=False forces the exact-attention prefill (degradation ladder).
        Raises :class:`resilience.NonFiniteError` if check_finite is set
        and logits come back NaN/Inf."""
        reg = obs.get_registry()
        b, s = prompts.shape
        assert b == self.batch
        if s + max_new > self.max_len:
            raise ValueError(
                f"prompt length {s} + max_new {max_new} overruns the "
                f"KV cache (max_len={self.max_len})")
        n_real = b if n_real is None else n_real
        batch_in = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if prompt_lens is not None:
            lens = np.asarray(prompt_lens, np.int32)
            assert lens.shape == (b,)
            if (lens < s).any():
                batch_in["pos_offset"] = jnp.asarray(s - lens, jnp.int32)
        prefill = self._prefill if mca else self._prefill_exact
        with reg.timer("serve.prefill_seconds"), obs.trace("engine.prefill"):
            cache, logits, stats = prefill(self.params, batch_in)
            logits = jax.block_until_ready(logits)
        logits = resilience.inject("serve.prefill", logits)
        if check_finite:
            resilience.check_finite(logits, "prefill logits")
        self._record_mca(stats, n_real / b)
        outs = []
        tok = jnp.argmax(jnp.asarray(logits)[..., :self.model.cfg.vocab_size],
                         axis=-1)
        outs.append(tok)
        t0 = time.perf_counter()
        with obs.trace("engine.decode_loop"):
            resilience.inject("serve.decode")
            for i in range(max_new - 1):
                t = jnp.asarray(s + i, jnp.int32)
                logits, cache = self._decode(self.params,
                                             tok.astype(jnp.int32), cache, t)
                tok = jnp.argmax(logits[..., :self.model.cfg.vocab_size],
                                 axis=-1)
                outs.append(tok)
            tok = jax.block_until_ready(tok)
        if max_new > 1:
            reg.histogram("serve.decode_step_seconds").observe(
                (time.perf_counter() - t0) / (max_new - 1))
            if check_finite:
                resilience.check_finite(np.asarray(logits),
                                        "decode logits")
        reg.counter("serve.generated_tokens").inc(n_real * max_new)
        return np.concatenate([np.asarray(t) for t in outs], axis=1)


class ContinuousBatcher:
    """Slot-based continuous batching with admission control, deadlines
    and a graceful-degradation ladder (see module docstring).  Finished
    slots immediately take the next queued request (prefill is re-run for
    the whole slot batch at toy scale; production would use per-slot
    prefill insertion)."""

    def __init__(self, engine: Engine, max_queue: Optional[int] = None,
                 max_retries: int = 1, backoff_s: float = 0.02):
        self.engine = engine
        self.max_queue = max_queue
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.queue: List[Request] = []
        self.done: Dict[int, List[int]] = {}
        self.status: Dict[int, str] = {}

    def _reject(self, req: Request, reason: str) -> str:
        req.status = REJECTED
        req.reason = reason
        self.status[req.uid] = REJECTED
        reg = obs.get_registry()
        reg.counter(f"serve.rejected.{reason}").inc()
        reg.counter("serve.rejected").inc()
        return REJECTED

    def submit(self, req: Request) -> str:
        """Admission control: validate against cache capacity and queue
        bound.  Returns the request's status ("queued" or "rejected")."""
        eng = self.engine
        if len(req.prompt) == 0:
            return self._reject(req, "empty_prompt")
        if len(req.prompt) + req.max_new > eng.max_len:
            return self._reject(req, "prompt_too_long")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            return self._reject(req, "queue_full")
        req.submit_t = time.monotonic()
        req.status = "queued"
        self.queue.append(req)
        return req.status

    def _finish(self, req: Request, status: str,
                tokens: Optional[List[int]] = None) -> None:
        req.status = status
        self.status[req.uid] = status
        if tokens is not None:
            req.out = tokens
            self.done[req.uid] = tokens
            obs.get_registry().counter("serve.requests_completed").inc()

    def _expired(self, req: Request, now: float) -> bool:
        return (req.deadline_s is not None
                and now - req.submit_t > req.deadline_s)

    def _run_wave(self, prompts, max_new, lens, n_real):
        """Degradation ladder: normal attempt, then retries with MCA
        disabled (exact attention).  Returns (gen, degraded) or raises the
        last error after max_retries exact retries."""
        reg = obs.get_registry()
        eng = self.engine
        try:
            return eng.generate(prompts, max_new, prompt_lens=lens,
                                n_real=n_real), False
        except ValueError:
            raise        # deterministic (capacity/shape): retrying can't help
        except Exception as e:                             # noqa: BLE001
            last = e
        for attempt in range(self.max_retries):
            reg.counter("resilience.serve.wave_retries").inc()
            log.warning("wave failed (%s); retry %d/%d with exact "
                        "attention", last, attempt + 1, self.max_retries)
            time.sleep(self.backoff_s * (2 ** attempt))
            try:
                gen = eng.generate(prompts, max_new, prompt_lens=lens,
                                   n_real=n_real, mca=False)
                if eng.mca_enabled:
                    reg.counter("resilience.serve.degraded_waves").inc()
                return gen, eng.mca_enabled
            except ValueError:
                raise
            except Exception as e:                         # noqa: BLE001
                last = e
        raise last

    def run(self) -> Dict[int, List[int]]:
        reg = obs.get_registry()
        b = self.engine.batch
        pad_id = self.engine.pad_id
        while self.queue:
            # deadline check at wave assembly: drop already-expired work
            now = time.monotonic()
            live = []
            for r in self.queue:
                if self._expired(r, now):
                    self._finish(r, TIMEOUT)
                    reg.counter("resilience.serve.timeouts").inc()
                else:
                    live.append(r)
            self.queue = live
            if not self.queue:
                break
            # capacity-aware wave assembly: a wave runs at s = max prompt
            # length and max_new = max over its members, so two
            # individually-admissible requests can jointly overrun the
            # cache — only add a request if the *joint* shape still fits;
            # the rest keep their order and go in the next wave.  (The
            # first pick always fits: submit validated it individually.)
            wave, rest = [], []
            s_max = new_max = 0
            for r in self.queue:
                cand_s = max(s_max, len(r.prompt))
                cand_new = max(new_max, r.max_new)
                if (len(wave) < b
                        and cand_s + cand_new <= self.engine.max_len):
                    wave.append(r)
                    s_max, new_max = cand_s, cand_new
                else:
                    rest.append(r)
            self.queue = rest
            n_real = len(wave)
            real = list(wave)
            while len(wave) < b:                       # pad with a dummy
                wave.append(Request(uid=-1, prompt=wave[0].prompt,
                                    max_new=wave[0].max_new))
            s = max(len(r.prompt) for r in wave)
            # left-pad with the designated pad id; pos_offset (below) makes
            # the padding invisible to attention and positions
            prompts = np.stack([
                np.pad(r.prompt, (s - len(r.prompt), 0),
                       constant_values=pad_id)
                for r in wave])
            lens = np.asarray([len(r.prompt) for r in wave], np.int32)
            max_new = max(r.max_new for r in wave)
            t0 = time.perf_counter()
            try:
                gen, degraded = self._run_wave(prompts, max_new, lens,
                                               n_real)
            except Exception as e:                         # noqa: BLE001
                log.error("wave failed after retries: %s", e)
                for r in real:
                    r.reason = str(e)
                    self._finish(r, FAILED)
                    reg.counter("resilience.serve.failed_requests").inc()
                continue
            reg.histogram("serve.wave_seconds").observe(
                time.perf_counter() - t0)
            reg.gauge("serve.slot_utilization").set(n_real / b)
            reg.counter("serve.waves").inc()
            now = time.monotonic()
            for i, r in enumerate(real):
                if self._expired(r, now):
                    self._finish(r, TIMEOUT)
                    reg.counter("resilience.serve.timeouts").inc()
                else:
                    self._finish(r, DEGRADED if degraded else OK,
                                 gen[i, :r.max_new].tolist())
        return self.done
