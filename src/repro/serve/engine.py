"""Serving: prefill + decode engine with a simple continuous batcher.

The engine wraps Model.prefill/Model.decode into jitted, cache-donating
steps; ``ContinuousBatcher`` multiplexes requests onto fixed decode slots
(vLLM-style slot reuse at toy scale — enough to drive the serving example
and tests end-to-end).

Ragged prompts are LEFT-padded with ``pad_id`` and per-row ``pos_offset``
amounts are threaded through prefill/decode: padding keys are masked out
of attention and RoPE/positions count from the first real token, so a
short prompt batched with a long one generates exactly what it would
alone (MCA off; with MCA on, capacity routing couples rows of a batch by
design).

Serving metrics land in the ``repro.obs`` registry: ``serve.prefill_seconds``,
``serve.decode_step_seconds``, ``serve.generated_tokens``,
``serve.flops_reduction``, ``serve.tier_occupancy.t{i}``, and per-wave
``serve.wave_seconds`` / ``serve.slot_utilization`` from the batcher.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models.api import Model, _logits


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 16
    out: Optional[List[int]] = None


class Engine:
    def __init__(self, model: Model, params, batch_size: int, max_len: int,
                 mca_enabled: bool = False, seed: int = 0, pad_id: int = 0):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.pad_id = pad_id
        self.key = jax.random.PRNGKey(seed) if mca_enabled else None

        cfg = model.cfg

        def prefill(params, batch_in):
            cache, hidden, stats = model.prefill(params, batch_in, max_len,
                                                 self.key)
            return cache, _logits(params, cfg, hidden[:, -1:]), stats

        def decode(params, tok, cache, t):
            return model.decode(params, tok, cache, t)

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(2,))

    def _record_mca(self, stats) -> None:
        reg = obs.get_registry()
        exact = float(stats["exact_flops"])
        mca = float(stats["mca_flops"])
        reg.counter("serve.mca_exact_flops").inc(exact)
        reg.counter("serve.mca_flops").inc(mca)
        # no MCA accounting (disabled / exact-only sites) -> neutral 1x
        reg.gauge("serve.flops_reduction").set(
            exact / mca if mca > 0 else 1.0)
        hist = np.asarray(stats["tier_hist"])
        for i, c in enumerate(hist):
            reg.counter(f"serve.tier_occupancy.t{i}").inc(float(c))

    def generate(self, prompts: np.ndarray, max_new: int,
                 greedy: bool = True,
                 prompt_lens: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts: [B, S] (left-padded if ragged). Returns [B, max_new]
        generated ids.  prompt_lens: optional [B] real prompt lengths —
        rows shorter than S get position offsets so left-padding is
        invisible to the model."""
        reg = obs.get_registry()
        b, s = prompts.shape
        assert b == self.batch
        batch_in = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if prompt_lens is not None:
            lens = np.asarray(prompt_lens, np.int32)
            assert lens.shape == (b,)
            if (lens < s).any():
                batch_in["pos_offset"] = jnp.asarray(s - lens, jnp.int32)
        with reg.timer("serve.prefill_seconds"), obs.trace("engine.prefill"):
            cache, logits, stats = self._prefill(self.params, batch_in)
            logits = jax.block_until_ready(logits)
        self._record_mca(stats)
        outs = []
        tok = jnp.argmax(logits[..., :self.model.cfg.vocab_size], axis=-1)
        outs.append(tok)
        t0 = time.perf_counter()
        with obs.trace("engine.decode_loop"):
            for i in range(max_new - 1):
                t = jnp.asarray(s + i, jnp.int32)
                logits, cache = self._decode(self.params,
                                             tok.astype(jnp.int32), cache, t)
                tok = jnp.argmax(logits[..., :self.model.cfg.vocab_size],
                                 axis=-1)
                outs.append(tok)
            tok = jax.block_until_ready(tok)
        if max_new > 1:
            reg.histogram("serve.decode_step_seconds").observe(
                (time.perf_counter() - t0) / (max_new - 1))
        reg.counter("serve.generated_tokens").inc(b * max_new)
        return np.concatenate([np.asarray(t) for t in outs], axis=1)


class ContinuousBatcher:
    """Slot-based continuous batching: finished slots immediately take the
    next queued request (prefill is re-run for the whole slot batch at toy
    scale; production would use per-slot prefill insertion)."""

    def __init__(self, engine: Engine):
        self.engine = engine
        self.queue: List[Request] = []
        self.done: Dict[int, List[int]] = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self) -> Dict[int, List[int]]:
        reg = obs.get_registry()
        b = self.engine.batch
        pad_id = self.engine.pad_id
        while self.queue:
            wave, self.queue = self.queue[:b], self.queue[b:]
            n_real = len(wave)
            while len(wave) < b:                       # pad with a dummy
                wave.append(Request(uid=-1, prompt=wave[0].prompt,
                                    max_new=wave[0].max_new))
            s = max(len(r.prompt) for r in wave)
            # left-pad with the designated pad id; pos_offset (below) makes
            # the padding invisible to attention and positions
            prompts = np.stack([
                np.pad(r.prompt, (s - len(r.prompt), 0),
                       constant_values=pad_id)
                for r in wave])
            lens = np.asarray([len(r.prompt) for r in wave], np.int32)
            max_new = max(r.max_new for r in wave)
            t0 = time.perf_counter()
            gen = self.engine.generate(prompts, max_new, prompt_lens=lens)
            reg.histogram("serve.wave_seconds").observe(
                time.perf_counter() - t0)
            reg.gauge("serve.slot_utilization").set(n_real / b)
            reg.counter("serve.waves").inc()
            for i, r in enumerate(wave):
                if r.uid >= 0:
                    self.done[r.uid] = gen[i, :r.max_new].tolist()
                    reg.counter("serve.requests_completed").inc()
        return self.done
