"""Serving: prefill + decode engine with a simple continuous batcher.

The engine wraps Model.prefill/Model.decode into jitted, cache-donating
steps; ``ContinuousBatcher`` multiplexes requests onto fixed decode slots
(vLLM-style slot reuse at toy scale — enough to drive the serving example
and tests end-to-end)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model, _logits


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 16
    out: Optional[List[int]] = None


class Engine:
    def __init__(self, model: Model, params, batch_size: int, max_len: int,
                 mca_enabled: bool = False, seed: int = 0):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed) if mca_enabled else None

        cfg = model.cfg

        def prefill(params, batch_in):
            cache, hidden = model.prefill(params, batch_in, max_len,
                                          self.key)
            return cache, _logits(params, cfg, hidden[:, -1:])

        def decode(params, tok, cache, t):
            return model.decode(params, tok, cache, t)

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(2,))

    def generate(self, prompts: np.ndarray, max_new: int,
                 greedy: bool = True) -> np.ndarray:
        """prompts: [B, S]. Returns [B, max_new] generated ids."""
        b, s = prompts.shape
        assert b == self.batch
        batch_in = {"tokens": jnp.asarray(prompts, jnp.int32)}
        cache, logits = self._prefill(self.params, batch_in)
        outs = []
        tok = jnp.argmax(logits[..., :self.model.cfg.vocab_size], axis=-1)
        outs.append(tok)
        for i in range(max_new - 1):
            t = jnp.asarray(s + i, jnp.int32)
            logits, cache = self._decode(self.params, tok.astype(jnp.int32),
                                         cache, t)
            tok = jnp.argmax(logits[..., :self.model.cfg.vocab_size], axis=-1)
            outs.append(tok)
        return np.concatenate([np.asarray(t) for t in outs], axis=1)


class ContinuousBatcher:
    """Slot-based continuous batching: finished slots immediately take the
    next queued request (prefill is re-run for the whole slot batch at toy
    scale; production would use per-slot prefill insertion)."""

    def __init__(self, engine: Engine):
        self.engine = engine
        self.queue: List[Request] = []
        self.done: Dict[int, List[int]] = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self) -> Dict[int, List[int]]:
        b = self.engine.batch
        while self.queue:
            wave, self.queue = self.queue[:b], self.queue[b:]
            while len(wave) < b:                       # pad with a dummy
                wave.append(Request(uid=-1, prompt=wave[0].prompt,
                                    max_new=wave[0].max_new))
            s = max(len(r.prompt) for r in wave)
            prompts = np.stack([
                np.pad(r.prompt, (s - len(r.prompt), 0), mode="edge")
                for r in wave])
            max_new = max(r.max_new for r in wave)
            gen = self.engine.generate(prompts, max_new)
            for i, r in enumerate(wave):
                if r.uid >= 0:
                    self.done[r.uid] = gen[i, :r.max_new].tolist()
        return self.done
