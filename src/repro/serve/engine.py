"""Serving: prefill + decode engine with per-slot continuous batching.

The engine wraps Model.prefill/Model.decode into jitted, cache-donating
steps.  Two batchers multiplex requests onto fixed decode slots:

* ``ContinuousBatcher`` — the legacy *wave* batcher: whenever a slot
  frees, prefill is re-run for the whole wave (every in-flight request is
  re-encoded).  Kept as the reference implementation and degradation
  oracle.
* ``SlotBatcher`` — real per-slot continuous batching (vLLM-style):
  ``Engine.prefill_into`` encodes ONE request (batch=1, MCA on, with the
  existing ragged masking/RoPE offsets) and splices its K/V pages and
  position state into the shared decode cache at a fixed slot index
  (``models.api.cache_insert_slot`` + the ``kernels.kv_slot_update``
  slot-sliced cache write), so occupied slots keep decoding while a freed
  slot admits the next queued request without touching anyone else's
  state.  The decode loop is sync-free on the hot path: per-row position,
  max-new countdown and finite flags live on device inside a
  ``lax.scan`` burst of K steps (``check_every``; K=1 under active chaos
  so fault-detection semantics match the per-step engine), and the host
  syncs once per burst to harvest tokens, admit queued work and check
  deadlines.

Ragged prompts are LEFT-padded with ``pad_id`` and per-row ``pos_offset``
amounts are threaded through prefill/decode: padding keys are masked out
of attention and RoPE/positions count from the first real token, so a
short prompt batched with a long one generates exactly what it would
alone (MCA off; with MCA on, capacity routing couples rows of a batch by
design).

Robustness (see ROADMAP.md § Robustness):

* **Admission control** — ``submit`` validates prompt length against the
  KV-cache capacity (``len(prompt) + max_new <= max_len``) and a bounded
  queue; rejected requests get ``status="rejected"`` with a reason and a
  ``serve.rejected.<reason>`` counter instead of crashing a wave later.
  Waves are assembled capacity-aware: a wave runs at the max prompt
  length / max ``max_new`` over its members, so requests that would
  jointly overrun ``max_len`` are deferred to the next wave rather than
  batched into a guaranteed failure.
* **Deadlines** — a request carrying ``deadline_s`` that has not finished
  within that budget of submission is dropped with ``status="timeout"``.
* **Degradation ladder** — a wave that raises or produces non-finite
  logits is retried (with backoff) with MCA *disabled*: exact attention
  reconstructs what the Monte-Carlo estimator corrupted (requests finish
  ``degraded`` rather than ``failed``).  Only when the exact retry also
  fails is the wave marked ``failed`` — the batcher never crashes.
* Per-request terminal status: ``ok | degraded | timeout | rejected |
  failed`` (on ``Request.status`` and ``ContinuousBatcher.status``).

Serving metrics land in the ``repro.obs`` registry: ``serve.prefill_seconds``,
``serve.decode_step_seconds``, ``serve.generated_tokens``,
``serve.prefill_tokens``, ``serve.insertions``,
``serve.prefill_tokens_saved``, ``serve.slot_idle_steps``,
``serve.flops_reduction``, ``serve.tier_occupancy.t{i}``, per-wave
``serve.wave_seconds`` / ``serve.slot_utilization`` (live-slot occupancy:
the fraction of slot-steps spent decoding real requests), admission
counters ``serve.rejected.*`` and recovery counters ``resilience.serve.*``.
Dummy padding slots in a partial wave are excluded from token and MCA
FLOPs accounting.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, resilience
from repro.models.api import Model, _logits, cache_insert_slot

log = logging.getLogger("repro.serve")

# terminal request statuses
OK, DEGRADED, TIMEOUT, REJECTED, FAILED = (
    "ok", "degraded", "timeout", "rejected", "failed")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 16
    deadline_s: Optional[float] = None    # wall budget from submit()
    out: Optional[List[int]] = None
    status: str = "queued"
    reason: Optional[str] = None          # set when rejected/failed
    submit_t: float = 0.0
    submit_pc: float = 0.0                # perf_counter stamp (tracing)


@dataclasses.dataclass
class SlotState:
    """Device-resident per-slot decode state for ``SlotBatcher``.

    All bookkeeping a decode step needs lives here so the hot loop never
    syncs to host: ``tok`` is each slot's last accepted token, ``t`` its
    next cache write position, ``steps_left`` its remaining decode-step
    budget (0 = idle slot; idle rows emit ``pad_id`` and do not advance).
    """

    cache: Any
    tok: jax.Array           # [B, 1] int32
    t: jax.Array             # [B] int32
    steps_left: jax.Array    # [B] int32


class Engine:
    def __init__(self, model: Model, params, batch_size: int, max_len: int,
                 mca_enabled: bool = False, seed: int = 0, pad_id: int = 0,
                 decode_obs_every: int = 8):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.pad_id = pad_id
        self.mca_enabled = mca_enabled
        self.decode_obs_every = max(1, decode_obs_every)
        self.key = jax.random.PRNGKey(seed) if mca_enabled else None

        cfg = model.cfg

        def make_prefill(key):
            def prefill(params, batch_in):
                cache, hidden, stats = model.prefill(params, batch_in,
                                                     max_len, key)
                return cache, _logits(params, cfg, hidden[:, -1:]), stats
            return jax.jit(prefill)

        def decode(params, tok, cache, t):
            return model.decode(params, tok, cache, t)

        def decode_step(params, tok, cache, t, bad):
            # fused decode + argmax + finite-flag accumulation: the host
            # never has to pull logits to pick the next token or check
            # health, so the loop is dispatch-bound
            logits, cache = model.decode(params, tok, cache, t)
            nxt = jnp.argmax(logits[..., :cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            bad = bad | ~jnp.all(jnp.isfinite(logits))
            return nxt, cache, t + jnp.int32(1), bad

        def make_prefill_into(key):
            def prefill_into(params, prompt, pos_offset, cache, tok, t,
                             steps_left, slot, new_steps):
                batch_in = {"tokens": prompt, "pos_offset": pos_offset}
                new_cache, hidden, stats = model.prefill(params, batch_in,
                                                         max_len, key)
                logits = _logits(params, cfg, hidden[:, -1:])
                cache = cache_insert_slot(cache, new_cache, slot)
                tok0 = jnp.argmax(logits[..., :cfg.vocab_size],
                                  axis=-1).astype(jnp.int32)
                tok = jax.lax.dynamic_update_slice(tok, tok0, (slot, 0))
                t = jax.lax.dynamic_update_slice(
                    t, jnp.full((1,), prompt.shape[1], jnp.int32), (slot,))
                steps_left = jax.lax.dynamic_update_slice(
                    steps_left, new_steps[None], (slot,))
                return cache, tok, t, steps_left, logits, stats
            return jax.jit(prefill_into, donate_argnums=(3, 4, 5, 6))

        def kill(steps_left, slot):
            return jax.lax.dynamic_update_slice(
                steps_left, jnp.zeros((1,), jnp.int32), (slot,))

        self._prefill = make_prefill(self.key)
        # exact-attention fallback path for the degradation ladder (same
        # trace as an MCA-off engine, so fallback output is token-identical)
        self._prefill_exact = (self._prefill if self.key is None
                               else make_prefill(None))
        self._decode = jax.jit(decode, donate_argnums=(2,))
        self._decode_step = jax.jit(decode_step, donate_argnums=(2, 3, 4))
        self._prefill_into = make_prefill_into(self.key)
        self._prefill_into_exact = (self._prefill_into if self.key is None
                                    else make_prefill_into(None))
        self._kill = jax.jit(kill)
        self._bursts: Dict = {}          # (k, eos_id) -> jitted scan burst
        # perf_counter windows of the most recent prefill / decode loop /
        # insertion / burst — batchers read these to attribute per-request
        # tracing spans without re-timing the jit calls
        self.last_prefill_t = (0.0, 0.0)
        self.last_decode_t = (0.0, 0.0)
        self.last_insert_t = (0.0, 0.0)
        self.last_burst_t = (0.0, 0.0)

    def _record_mca(self, stats, frac: float) -> None:
        """frac: fraction of batch rows that are real requests — dummy
        padding slots must not inflate MCA FLOPs accounting."""
        reg = obs.get_registry()
        exact = float(stats["exact_flops"]) * frac
        mca = float(stats["mca_flops"]) * frac
        reg.counter("serve.mca_exact_flops").inc(exact)
        reg.counter("serve.mca_flops").inc(mca)
        # no MCA accounting (disabled / exact-only sites) -> neutral 1x
        reg.gauge("serve.flops_reduction").set(
            exact / mca if mca > 0 else 1.0)
        hist = np.asarray(stats["tier_hist"])
        for i, c in enumerate(hist):
            reg.counter(f"serve.tier_occupancy.t{i}").inc(float(c) * frac)

    def generate(self, prompts: np.ndarray, max_new: int,
                 greedy: bool = True,
                 prompt_lens: Optional[np.ndarray] = None,
                 n_real: Optional[int] = None,
                 mca: bool = True,
                 check_finite: bool = True) -> np.ndarray:
        """prompts: [B, S] (left-padded if ragged). Returns [B, max_new]
        generated ids.  prompt_lens: optional [B] real prompt lengths —
        rows shorter than S get position offsets so left-padding is
        invisible to the model.  n_real: rows that are real requests (the
        rest are dummy padding slots, excluded from token/FLOPs metrics).
        mca=False forces the exact-attention prefill (degradation ladder).
        Raises :class:`resilience.NonFiniteError` if check_finite is set
        and logits come back NaN/Inf."""
        reg = obs.get_registry()
        b, s = prompts.shape
        assert b == self.batch
        if s + max_new > self.max_len:
            raise ValueError(
                f"prompt length {s} + max_new {max_new} overruns the "
                f"KV cache (max_len={self.max_len})")
        n_real = b if n_real is None else n_real
        batch_in = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if prompt_lens is not None:
            lens = np.asarray(prompt_lens, np.int32)
            assert lens.shape == (b,)
            if (lens < s).any():
                batch_in["pos_offset"] = jnp.asarray(s - lens, jnp.int32)
        prefill = self._prefill if mca else self._prefill_exact
        t0p = time.perf_counter()
        with obs.trace("engine.prefill"):
            cache, logits, stats = prefill(self.params, batch_in)
            logits = jax.block_until_ready(logits)
        t1p = time.perf_counter()
        reg.histogram("serve.prefill_seconds").observe(t1p - t0p)
        self.last_prefill_t = (t0p, t1p)
        obs.record_span("prefill", t0p, t1p, cat="serve.engine",
                        track="engine",
                        args={"batch": b, "s": int(s), "mca": bool(mca)})
        logits = resilience.inject("serve.prefill", logits)
        if check_finite:
            resilience.check_finite(logits, "prefill logits")
        self._record_mca(stats, n_real / b)
        reg.counter("serve.prefill_tokens").inc(b * s)
        # int32 cast hoisted out of the loop; position and finite flags stay
        # on device — the only host syncs are the K-step latency observes
        tok = jnp.argmax(jnp.asarray(logits)[..., :self.model.cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
        outs = [tok]
        t_dev = jnp.asarray(s, jnp.int32)
        bad = jnp.zeros((), bool)
        hist = reg.histogram("serve.decode_step_seconds")
        obs_every = self.decode_obs_every
        since = 0
        t0d = t_last = time.perf_counter()
        with obs.trace("engine.decode_loop"):
            resilience.inject("serve.decode")
            for _ in range(max_new - 1):
                tok, cache, t_dev, bad = self._decode_step(
                    self.params, tok, cache, t_dev, bad)
                outs.append(tok)
                since += 1
                if since == obs_every:
                    jax.block_until_ready(tok)
                    now = time.perf_counter()
                    hist.observe((now - t_last) / since)
                    t_last, since = now, 0
            tok = jax.block_until_ready(tok)
        if since:
            hist.observe((time.perf_counter() - t_last) / since)
        t1d = time.perf_counter()
        self.last_decode_t = (t0d, t1d)
        obs.record_span("decode_loop", t0d, t1d, cat="serve.engine",
                        track="engine", args={"steps": max_new - 1})
        if max_new > 1 and check_finite and bool(bad):
            raise resilience.NonFiniteError(
                "non-finite values in decode logits")
        reg.counter("serve.generated_tokens").inc(n_real * max_new)
        return np.concatenate([np.asarray(t) for t in outs], axis=1)

    # ------------------------------------------- per-slot insertion path
    def init_slot_state(self) -> SlotState:
        """Fresh all-idle slot state for a ``SlotBatcher`` session."""
        return SlotState(
            cache=self.model.init_cache(self.batch, self.max_len),
            tok=jnp.zeros((self.batch, 1), jnp.int32),
            t=jnp.zeros((self.batch,), jnp.int32),
            steps_left=jnp.zeros((self.batch,), jnp.int32))

    def prefill_bucket(self, prompt_len: int, max_new: int) -> int:
        """Pow-2 padded prompt length, so insertion compiles once per
        bucket instead of once per prompt length (clamped so the slot's
        decode positions still fit the cache)."""
        s_pad = 8
        while s_pad < prompt_len:
            s_pad *= 2
        return max(prompt_len, min(s_pad, self.max_len - max_new))

    def prefill_into(self, prompt: np.ndarray, state: SlotState, slot: int,
                     max_new: int, mca: bool = True):
        """Encode ONE request (batch=1, left-padded to a pow-2 bucket,
        MCA on unless ``mca=False``) and donate/write its K/V pages and
        position state into the shared decode cache at ``slot``.

        Returns ``(state, first_token, s_pad)``.  Raises
        :class:`resilience.NonFiniteError` when the insertion logits come
        back non-finite (the ``serve.insert`` injection point taps the
        logits first) — the slot's state is still consistently
        overwritten, so an exact-attention retry into the same slot is
        safe.  Other slots' device state is untouched either way.
        """
        reg = obs.get_registry()
        n = len(prompt)
        if n + max_new > self.max_len:
            raise ValueError(
                f"prompt length {n} + max_new {max_new} overruns the "
                f"KV cache (max_len={self.max_len})")
        s_pad = self.prefill_bucket(n, max_new)
        padded = np.full((1, s_pad), self.pad_id, np.int32)
        padded[0, s_pad - n:] = prompt
        fn = self._prefill_into if mca else self._prefill_into_exact
        t0 = time.perf_counter()
        with obs.trace("engine.insert"):
            cache, tok, t, steps_left, logits, stats = fn(
                self.params, jnp.asarray(padded),
                jnp.asarray([s_pad - n], jnp.int32), state.cache,
                state.tok, state.t, state.steps_left,
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(max_new - 1, jnp.int32))
            logits = jax.block_until_ready(logits)
        t1 = time.perf_counter()
        reg.histogram("serve.prefill_seconds").observe(t1 - t0)
        self.last_insert_t = (t0, t1)
        obs.record_span("insert", t0, t1, cat="serve.engine", track="engine",
                        args={"slot": slot, "s_pad": s_pad, "mca": bool(mca)})
        state = SlotState(cache, tok, t, steps_left)
        reg.counter("serve.insertions").inc()
        reg.counter("serve.prefill_tokens").inc(s_pad)
        self._record_mca(stats, 1.0)
        try:
            logits_np = resilience.inject("serve.insert", np.asarray(logits))
            resilience.check_finite(logits_np, "insert logits")
        except Exception as e:
            # the old state was donated into the jit call — hand callers
            # the (consistent) new state so they can retry into the slot
            e.slot_state = state
            raise
        first = int(logits_np[0, 0, :self.model.cfg.vocab_size].argmax())
        return state, first, s_pad

    def _make_burst(self, k: int, eos_id: Optional[int]):
        model, cfg = self.model, self.model.cfg
        pad_id = self.pad_id

        def burst(params, tok, cache, t, steps_left):
            def step(carry, _):
                tok, cache, t, steps_left = carry
                live = steps_left > 0
                logits, cache = model.decode(params, tok, cache, t)
                nxt = jnp.argmax(logits[..., :cfg.vocab_size],
                                 axis=-1).astype(jnp.int32)       # [B, 1]
                ok = jnp.all(jnp.isfinite(
                    logits.reshape(logits.shape[0], -1)), axis=-1)
                # idle rows emit pad, keep their token/position frozen
                # (their stale cache row is fully rewritten on insertion)
                nxt = jnp.where(live[:, None], nxt, jnp.int32(pad_id))
                tok = jnp.where(live[:, None], nxt, tok)
                t = t + live.astype(jnp.int32)
                steps_left = jnp.where(
                    live, jnp.maximum(steps_left - 1, 0), steps_left)
                if eos_id is not None:
                    steps_left = jnp.where(live & (nxt[:, 0] == eos_id),
                                           0, steps_left)
                return (tok, cache, t, steps_left), (nxt[:, 0], live & ~ok,
                                                     live)

            (tok, cache, t, steps_left), (toks, bads, lives) = jax.lax.scan(
                step, (tok, cache, t, steps_left), None, length=k)
            return (tok, cache, t, steps_left, toks.T,
                    jnp.any(bads, axis=0), jnp.sum(lives))

        return jax.jit(burst, donate_argnums=(1, 2, 3, 4))

    def decode_burst(self, state: SlotState, k: int,
                     eos_id: Optional[int] = None):
        """Run ``k`` decode steps over all slots without touching the
        host: per-row position, max-new countdown, EOS and finite flags
        are device-side inside one ``lax.scan``.  Returns
        ``(state, toks [B, k], bad [B], live_steps)`` — reading the
        returned arrays is the single device→host sync per burst."""
        fn = self._bursts.get((k, eos_id))
        if fn is None:
            fn = self._bursts[(k, eos_id)] = self._make_burst(k, eos_id)
        t0 = time.perf_counter()
        with obs.trace("engine.decode_burst"):
            tok, cache, t, steps_left, toks, bad, live = fn(
                self.params, state.tok, state.cache, state.t,
                state.steps_left)
        state = SlotState(cache, tok, t, steps_left)
        toks, bad, live = np.asarray(toks), np.asarray(bad), int(live)
        t1 = time.perf_counter()
        self.last_burst_t = (t0, t1)
        obs.record_span("decode_burst", t0, t1, cat="serve.engine",
                        track="engine", args={"k": k, "live_steps": live})
        return state, toks, bad, live

    def kill_slot(self, state: SlotState, slot: int) -> SlotState:
        """Zero a slot's decode budget (deadline expiry) on device."""
        return dataclasses.replace(
            state, steps_left=self._kill(state.steps_left,
                                         jnp.asarray(slot, jnp.int32)))


class ContinuousBatcher:
    """Slot-based continuous batching with admission control, deadlines
    and a graceful-degradation ladder (see module docstring).  Finished
    slots immediately take the next queued request (prefill is re-run for
    the whole slot batch at toy scale; production would use per-slot
    prefill insertion).

    When tracing is enabled (``obs.enable_tracing``), each request gets a
    span chain ``queue → prefill → decode → finish`` on the track
    ``<trace_cat>/req<uid>``."""

    trace_cat = "serve.wave"

    def __init__(self, engine: Engine, max_queue: Optional[int] = None,
                 max_retries: int = 1, backoff_s: float = 0.02):
        self.engine = engine
        self.max_queue = max_queue
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.queue: List[Request] = []
        self.done: Dict[int, List[int]] = {}
        self.status: Dict[int, str] = {}

    def _reject(self, req: Request, reason: str) -> str:
        req.status = REJECTED
        req.reason = reason
        self.status[req.uid] = REJECTED
        reg = obs.get_registry()
        reg.counter(f"serve.rejected.{reason}").inc()
        reg.counter("serve.rejected").inc()
        return REJECTED

    def submit(self, req: Request) -> str:
        """Admission control: validate against cache capacity and queue
        bound.  Returns the request's status ("queued" or "rejected")."""
        eng = self.engine
        if len(req.prompt) == 0:
            return self._reject(req, "empty_prompt")
        if len(req.prompt) + req.max_new > eng.max_len:
            return self._reject(req, "prompt_too_long")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            return self._reject(req, "queue_full")
        req.submit_t = time.monotonic()
        req.submit_pc = time.perf_counter()
        req.status = "queued"
        self.queue.append(req)
        return req.status

    def _track(self, req: Request) -> str:
        return f"{self.trace_cat}/req{req.uid}"

    def _finish(self, req: Request, status: str,
                tokens: Optional[List[int]] = None) -> None:
        req.status = status
        self.status[req.uid] = status
        obs.mark("finish", cat=self.trace_cat, track=self._track(req),
                 args={"status": status})
        if tokens is not None:
            req.out = tokens
            self.done[req.uid] = tokens
            obs.get_registry().counter("serve.requests_completed").inc()

    def _expired(self, req: Request, now: float) -> bool:
        return (req.deadline_s is not None
                and now - req.submit_t > req.deadline_s)

    def _run_wave(self, prompts, max_new, lens, n_real):
        """Degradation ladder: normal attempt, then retries with MCA
        disabled (exact attention).  Returns (gen, degraded) or raises the
        last error after max_retries exact retries."""
        reg = obs.get_registry()
        eng = self.engine
        try:
            return eng.generate(prompts, max_new, prompt_lens=lens,
                                n_real=n_real), False
        except ValueError:
            raise        # deterministic (capacity/shape): retrying can't help
        except Exception as e:                             # noqa: BLE001
            last = e
        for attempt in range(self.max_retries):
            reg.counter("resilience.serve.wave_retries").inc()
            log.warning("wave failed (%s); retry %d/%d with exact "
                        "attention", last, attempt + 1, self.max_retries)
            time.sleep(self.backoff_s * (2 ** attempt))
            try:
                gen = eng.generate(prompts, max_new, prompt_lens=lens,
                                   n_real=n_real, mca=False)
                if eng.mca_enabled:
                    reg.counter("resilience.serve.degraded_waves").inc()
                return gen, eng.mca_enabled
            except ValueError:
                raise
            except Exception as e:                         # noqa: BLE001
                last = e
        raise last

    def run(self) -> Dict[int, List[int]]:
        reg = obs.get_registry()
        b = self.engine.batch
        pad_id = self.engine.pad_id
        while self.queue:
            # deadline check at wave assembly: drop already-expired work
            now = time.monotonic()
            live = []
            for r in self.queue:
                if self._expired(r, now):
                    self._finish(r, TIMEOUT)
                    reg.counter("resilience.serve.timeouts").inc()
                else:
                    live.append(r)
            self.queue = live
            if not self.queue:
                break
            # capacity-aware wave assembly: a wave runs at s = max prompt
            # length and max_new = max over its members, so two
            # individually-admissible requests can jointly overrun the
            # cache — only add a request if the *joint* shape still fits;
            # the rest keep their order and go in the next wave.  (The
            # first pick always fits: submit validated it individually.)
            wave, rest = [], []
            s_max = new_max = 0
            for r in self.queue:
                cand_s = max(s_max, len(r.prompt))
                cand_new = max(new_max, r.max_new)
                if (len(wave) < b
                        and cand_s + cand_new <= self.engine.max_len):
                    wave.append(r)
                    s_max, new_max = cand_s, cand_new
                else:
                    rest.append(r)
            self.queue = rest
            n_real = len(wave)
            real = list(wave)
            while len(wave) < b:                       # pad with a dummy
                wave.append(Request(uid=-1, prompt=wave[0].prompt,
                                    max_new=wave[0].max_new))
            s = max(len(r.prompt) for r in wave)
            # left-pad with the designated pad id; pos_offset (below) makes
            # the padding invisible to attention and positions
            prompts = np.stack([
                np.pad(r.prompt, (s - len(r.prompt), 0),
                       constant_values=pad_id)
                for r in wave])
            lens = np.asarray([len(r.prompt) for r in wave], np.int32)
            max_new = max(r.max_new for r in wave)
            t0 = time.perf_counter()
            if obs.tracing_enabled():
                for r in real:       # queued-until-wave-start per request
                    obs.record_span("queue", r.submit_pc, t0,
                                    cat=self.trace_cat, track=self._track(r))
            try:
                gen, degraded = self._run_wave(prompts, max_new, lens,
                                               n_real)
            except Exception as e:                         # noqa: BLE001
                log.error("wave failed after retries: %s", e)
                for r in real:
                    r.reason = str(e)
                    self._finish(r, FAILED)
                    reg.counter("resilience.serve.failed_requests").inc()
                continue
            t1 = time.perf_counter()
            reg.histogram("serve.wave_seconds").observe(t1 - t0)
            if obs.tracing_enabled():
                # attribute the wave's engine windows to every member so
                # each request track shows its own prefill/decode spans
                obs.record_span("wave", t0, t1, cat=self.trace_cat,
                                track="waves",
                                args={"n_real": n_real,
                                      "degraded": degraded})
                for r in real:
                    obs.record_span("prefill", *self.engine.last_prefill_t,
                                    cat=self.trace_cat,
                                    track=self._track(r),
                                    args={"degraded": degraded})
                    obs.record_span("decode", *self.engine.last_decode_t,
                                    cat=self.trace_cat,
                                    track=self._track(r),
                                    args={"steps": max_new - 1})
            # live-slot occupancy: fraction of slot-steps this wave spent
            # decoding real requests (dummy slots and rows idling past
            # their own max_new count as idle) — agrees with the
            # SlotBatcher's serve.slot_idle_steps accounting
            reg.gauge("serve.slot_utilization").set(
                sum(min(r.max_new, max_new) for r in real) / (b * max_new))
            reg.counter("serve.waves").inc()
            now = time.monotonic()
            for i, r in enumerate(real):
                if self._expired(r, now):
                    self._finish(r, TIMEOUT)
                    reg.counter("resilience.serve.timeouts").inc()
                else:
                    self._finish(r, DEGRADED if degraded else OK,
                                 gen[i, :r.max_new].tolist())
        return self.done


class SlotBatcher(ContinuousBatcher):
    """Per-slot continuous batching: freed slots admit queued requests via
    ``Engine.prefill_into`` (one batch=1 prefill spliced into the shared
    cache) while occupied slots keep decoding — nothing is re-encoded.

    Inherits the wave batcher's admission control / deadline / status
    surface; the degradation ladder moves to per-REQUEST granularity:

    * insertion failure (raise or non-finite via the ``serve.insert``
      injection point) retries that ONE request with exact attention —
      other slots never notice; past ``max_retries`` only that request is
      ``failed``.
    * a slot whose decode turns non-finite is re-inserted from its prompt
      with exact attention (``resilience.serve.decode_restarts``) and its
      output regenerated from scratch.
    * decode-step faults (``serve.decode`` injection) retry the burst;
      past ``max_retries`` the whole in-flight set fails and the device
      state is rebuilt fresh.

    The decode loop runs ``check_every``-step device bursts; under active
    chaos plans the burst shrinks to 1 step so fault detection matches the
    per-step engine semantics.
    """

    trace_cat = "serve.per_slot"

    def __init__(self, engine: Engine, max_queue: Optional[int] = None,
                 max_retries: int = 1, backoff_s: float = 0.02,
                 check_every: int = 8, eos_id: Optional[int] = None):
        super().__init__(engine, max_queue=max_queue,
                         max_retries=max_retries, backoff_s=backoff_s)
        self.check_every = max(1, check_every)
        self.eos_id = eos_id

    def _insert(self, state: SlotState, slot: int, req: Request,
                occupied_pads: List[int]):
        """Prefill one request into ``slot`` with the per-request
        degradation ladder.  Returns ``(state, meta_or_None)``."""
        reg = obs.get_registry()
        eng = self.engine
        last = None
        for attempt in range(self.max_retries + 1):
            use_mca = attempt == 0
            if attempt:
                reg.counter("resilience.serve.insert_retries").inc()
                log.warning("insert failed (%s); retry %d/%d with exact "
                            "attention", last, attempt, self.max_retries)
                time.sleep(self.backoff_s * (2 ** (attempt - 1)))
            try:
                state, first, s_pad = eng.prefill_into(
                    req.prompt, state, slot, req.max_new, mca=use_mca)
            except ValueError:
                raise    # deterministic (capacity): retrying can't help
            except Exception as e:                         # noqa: BLE001
                # recover the post-insertion state (the pre-insertion
                # buffers were donated into the failed attempt)
                state = getattr(e, "slot_state", state)
                last = e
                continue
            degraded = attempt > 0 and eng.mca_enabled
            if degraded:
                reg.counter("resilience.serve.degraded_requests").inc()
            obs.record_span("prefill", *eng.last_insert_t,
                            cat=self.trace_cat, track=self._track(req),
                            args={"slot": slot, "s_pad": s_pad,
                                  "degraded": degraded})
            # what a wave batcher would have re-prefilled right now: every
            # OTHER occupied slot's padded prompt
            reg.counter("serve.prefill_tokens_saved").inc(
                sum(occupied_pads))
            done = (self.eos_id is not None
                    and first == self.eos_id) or req.max_new == 1
            return state, {"req": req, "s_pad": s_pad,
                           "remaining": 0 if done else req.max_new - 1,
                           "out": [first], "degraded": degraded}
        req.reason = str(last)
        self._finish(req, FAILED)
        reg.counter("resilience.serve.failed_requests").inc()
        # the failed insertion may have armed the slot's decode budget
        return eng.kill_slot(state, slot), None

    def _finish_slot(self, meta) -> None:
        req = meta["req"]
        self._finish(req, DEGRADED if meta["degraded"] else OK,
                     meta["out"][:req.max_new])
        obs.get_registry().counter("serve.generated_tokens").inc(
            len(meta["out"][:req.max_new]))

    def run(self) -> Dict[int, List[int]]:
        reg = obs.get_registry()
        eng = self.engine
        b = eng.batch
        state = eng.init_slot_state()
        slots: List[Optional[dict]] = [None] * b
        decode_failures = 0
        cum_live = cum_total = 0
        while self.queue or any(s is not None for s in slots):
            now = time.monotonic()
            # drop expired queued work before it wastes an insertion
            live_q = []
            for r in self.queue:
                if self._expired(r, now):
                    self._finish(r, TIMEOUT)
                    reg.counter("resilience.serve.timeouts").inc()
                else:
                    live_q.append(r)
            self.queue = live_q
            # admit queued requests into free slots, one insertion each
            for slot in range(b):
                if slots[slot] is not None or not self.queue:
                    continue
                req = self.queue.pop(0)
                obs.record_span("queue", req.submit_pc, time.perf_counter(),
                                cat=self.trace_cat, track=self._track(req))
                pads = [m["s_pad"] for m in slots if m is not None]
                state, meta = self._insert(state, slot, req, pads)
                if meta is None:
                    continue
                if meta["remaining"] <= 0:
                    self._finish_slot(meta)
                else:
                    slots[slot] = meta
            if not any(s is not None for s in slots):
                continue        # failures drained work; check queue again
            # K-step sync-free burst; K=1 under chaos so injected faults
            # surface with per-step granularity
            eff_k = 1 if resilience.active() else self.check_every
            t0 = time.perf_counter()
            try:
                resilience.inject("serve.decode")
                state, toks, bad, live_steps = eng.decode_burst(
                    state, eff_k, self.eos_id)
            except Exception as e:                         # noqa: BLE001
                decode_failures += 1
                reg.counter("resilience.serve.decode_retries").inc()
                if decode_failures > self.max_retries:
                    log.error("decode failed after retries: %s", e)
                    for slot in range(b):
                        if slots[slot] is None:
                            continue
                        req = slots[slot]["req"]
                        req.reason = str(e)
                        self._finish(req, FAILED)
                        reg.counter(
                            "resilience.serve.failed_requests").inc()
                        slots[slot] = None
                    state = eng.init_slot_state()
                    decode_failures = 0
                else:
                    log.warning("decode burst failed (%s); retry %d/%d",
                                e, decode_failures, self.max_retries)
                    time.sleep(self.backoff_s * (2 ** decode_failures))
                continue
            decode_failures = 0
            reg.histogram("serve.decode_step_seconds").observe(
                (time.perf_counter() - t0) / eff_k)
            if obs.tracing_enabled():
                for s_meta in slots:      # one decode span per live slot
                    if s_meta is not None:
                        obs.record_span("decode", *eng.last_burst_t,
                                        cat=self.trace_cat,
                                        track=self._track(s_meta["req"]),
                                        args={"k": eff_k})
            reg.counter("serve.slot_idle_steps").inc(
                eff_k * b - live_steps)
            cum_live += live_steps
            cum_total += eff_k * b
            reg.gauge("serve.slot_utilization").set(cum_live / cum_total)
            now = time.monotonic()
            for slot in range(b):
                meta = slots[slot]
                if meta is None:
                    continue
                req = meta["req"]
                take = min(meta["remaining"], eff_k)
                got = toks[slot, :take].tolist()
                if self.eos_id is not None and self.eos_id in got:
                    got = got[:got.index(self.eos_id) + 1]
                meta["out"].extend(got)
                meta["remaining"] -= len(got)
                if bool(bad[slot]):
                    state, meta = self._restart_exact(state, slot, req)
                    if meta is not None and meta["remaining"] <= 0:
                        self._finish_slot(meta)
                        meta = None
                    slots[slot] = meta
                elif self._expired(req, now):
                    self._finish(req, TIMEOUT)
                    reg.counter("resilience.serve.timeouts").inc()
                    state = eng.kill_slot(state, slot)
                    slots[slot] = None
                elif (meta["remaining"] <= 0
                      or (self.eos_id is not None
                          and got and got[-1] == self.eos_id)):
                    self._finish_slot(meta)
                    slots[slot] = None
        return self.done

    def _restart_exact(self, state: SlotState, slot: int, req: Request):
        """A slot's decode went non-finite: rebuild it from its prompt
        with exact attention and regenerate from scratch.  Returns
        ``(state, meta_or_None)`` — None means the request failed."""
        reg = obs.get_registry()
        eng = self.engine
        reg.counter("resilience.serve.decode_restarts").inc()
        log.warning("slot %d produced non-finite logits; restarting with "
                    "exact attention", slot)
        try:
            state, first, s_pad = eng.prefill_into(
                req.prompt, state, slot, req.max_new, mca=False)
        except Exception as e:                             # noqa: BLE001
            state = getattr(e, "slot_state", state)
            req.reason = str(e)
            self._finish(req, FAILED)
            reg.counter("resilience.serve.failed_requests").inc()
            return eng.kill_slot(state, slot), None
        degraded = eng.mca_enabled
        if degraded:
            reg.counter("resilience.serve.degraded_requests").inc()
        obs.record_span("prefill", *eng.last_insert_t, cat=self.trace_cat,
                        track=self._track(req),
                        args={"slot": slot, "restart": True,
                              "degraded": degraded})
        done = (self.eos_id is not None
                and first == self.eos_id) or req.max_new == 1
        return state, {"req": req, "s_pad": s_pad,
                       "remaining": 0 if done else req.max_new - 1,
                       "out": [first], "degraded": degraded}
