from .pipeline import MemmapLM, Prefetcher, SyntheticLM, write_token_file
