"""Data pipeline: deterministic synthetic LM stream + memmap token files,
per-host sharding, background prefetch.

Determinism contract: batch(step, host) is a pure function of
(seed, step, host) — restarts replay the exact stream, which is what makes
checkpoint/restart bitwise reproducible (fault tolerance substrate).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro import resilience


class SyntheticLM:
    """Deterministic synthetic next-token data with learnable structure.

    Sequences follow a seeded Markov-ish pattern (token_{t+1} depends on
    token_t) so that training loss measurably decreases — smoke-level
    learnability without external data.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, n_hosts: int = 1, host_id: int = 0,
                 extras: Optional[Dict] = None):
        assert global_batch % n_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // n_hosts
        self.seed = seed
        self.host = host_id
        self.extras = extras or {}
        rng = np.random.default_rng(seed + 1234)
        self._succ = rng.integers(0, vocab_size,
                                  size=(vocab_size, 4), dtype=np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        resilience.inject("data.batch")
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host)
        b, s = self.local_batch, self.seq
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        branch = rng.integers(0, 4, size=(b, s))
        noise = rng.random((b, s)) < 0.05
        rand_tok = rng.integers(0, self.vocab, size=(b, s))
        for t in range(s):
            nxt = self._succ[toks[:, t], branch[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        for k, fn in self.extras.items():
            out[k] = fn(rng, b)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def write_token_file(path: str, tokens: np.ndarray) -> None:
    """Persist a token stream as a raw uint32 memmap file."""
    arr = np.asarray(tokens, np.uint32)
    with open(path, "wb") as f:
        f.write(arr.tobytes())


class MemmapLM:
    """Token-file-backed stream with deterministic window sampling."""

    def __init__(self, path: str, vocab_size: int, seq_len: int,
                 global_batch: int, *, seed: int = 0, n_hosts: int = 1,
                 host_id: int = 0):
        assert global_batch % n_hosts == 0
        self.data = np.memmap(path, dtype=np.uint32, mode="r")
        assert len(self.data) > seq_len + 1, "token file too small"
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // n_hosts
        self.seed = seed
        self.host = host_id

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        resilience.inject("data.batch")
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host)
        starts = rng.integers(0, len(self.data) - self.seq - 1,
                              size=self.local_batch)
        rows = np.stack([self.data[s:s + self.seq + 1] for s in starts])
        rows = rows.astype(np.int32) % self.vocab
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with bounded queue (overlaps host data
    work with device compute).

    A crash in the source used to kill the worker thread silently, leaving
    ``next()`` blocked forever; now the exception is captured and re-raised
    from ``next()`` on the consumer thread — on *every* call after the
    crash (the worker is gone, so a blocking ``q.get()`` would never be
    fed again; ``_exc`` stays set and is tested before touching the
    queue)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self._exc = None
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            try:
                batch = self.source.batch(step)
            except BaseException as e:                     # noqa: BLE001
                self._exc = e
                item = (None, None)       # wake a blocked consumer
            else:
                item = (step, batch)
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            if self._exc is not None:
                return
            step += 1

    def next(self):
        # fail fast forever once the source has crashed: the worker thread
        # has exited, so blocking on the (empty) queue would hang
        if self._exc is not None:
            raise self._exc
        item = self.q.get()
        if item[1] is None and self._exc is not None:
            raise self._exc
        return item

    def close(self):
        self._stop.set()
        self.thread.join(timeout=2)
