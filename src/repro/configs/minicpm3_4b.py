"""minicpm3-4b [hf:openbmb/MiniCPM3-4B] — MLA attention.

62L d_model=2560 40H d_ff=6400 vocab=73448. MLA dims follow the HF config
family: q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32, v 64.
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_head=64,
        d_ff=6400, vocab_size=73448,
        attn_type="mla", mla_q_lora=768, mla_kv_lora=256,
        mla_qk_nope=64, mla_qk_rope=32, mla_v_dim=64,
        ffn_type="swiglu", norm_type="rmsnorm", tie_embeddings=True,
    ).replace(**overrides)
