"""Architecture registry: --arch <id> resolves through ARCHS."""
from __future__ import annotations

import importlib

ARCHS = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-32b": "qwen3_32b",
    "chatglm3-6b": "chatglm3_6b",
    "minicpm3-4b": "minicpm3_4b",
    "internvl2-1b": "internvl2_1b",
    "whisper-small": "whisper_small",
    "mamba2-2.7b": "mamba2_2p7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "bert-base": "bert_base",
}

# per-arch shape sets (assignment): name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing)
LONG_OK = {"mamba2-2.7b", "recurrentgemma-9b"}


def get_config(arch: str, **overrides):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.config(**overrides)


def cells(include_bert: bool = False):
    """All assigned (arch x shape) dry-run cells, honoring skips."""
    out = []
    for arch in ARCHS:
        if arch == "bert-base" and not include_bert:
            continue
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            out.append((arch, shape))
    return out
