"""starcoder2-3b [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152. GQA + RoPE,
GeLU FFN, LayerNorm, tied embeddings.
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
        d_ff=12288, vocab_size=49152,
        ffn_type="gelu", norm_type="layernorm", tie_embeddings=True,
    ).replace(**overrides)
