"""qwen3-32b [hf:Qwen/Qwen3-32B-class config per assignment].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk_norm.
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=25600, vocab_size=151936, qk_norm=True,
        rope_theta=1_000_000.0,
        ffn_type="swiglu", norm_type="rmsnorm",
    ).replace(**overrides)
