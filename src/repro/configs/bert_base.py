"""BERT-base — the paper's own evaluation model (Devlin et al. 2019).

12L d_model=768 12H d_ff=3072 vocab=30522, bidirectional encoder,
absolute sinusoidal positions, GeLU, LayerNorm. Used by benchmarks/
(GLUE-style tables 1-2); distil variant = 6 layers.
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="bert-base",
        family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
        d_ff=3072, vocab_size=30522, causal=False, rotary_pct=0.0,
        add_sinusoidal_pos=True,
        ffn_type="gelu", norm_type="layernorm", tie_embeddings=True,
    ).replace(**overrides)
