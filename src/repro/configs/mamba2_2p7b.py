"""mamba2-2.7b [arXiv:2405.21060] — SSD (state-space duality), attn-free.

64L d_model=2560 vocab=50280, ssm_state=128, headdim=64, expand=2
(d_inner=5120, 80 heads). MCA inapplicable (no attention matrix) — see
DESIGN.md §Arch-applicability.
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_head=0,
        d_ff=0, vocab_size=50280, attn_type="none",
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=64,
        norm_type="rmsnorm", tie_embeddings=True,
    ).replace(**overrides)
