"""olmoe-1b-7b [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert, MoE 64 experts top-8,
vocab 50304. OLMoE uses QK-norm.
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1024, vocab_size=50304,
        n_experts=64, top_k=8, qk_norm=True,
        ffn_type="swiglu", norm_type="rmsnorm",
    ).replace(**overrides)
