"""recurrentgemma-9b [arXiv:2402.19427] — RG-LRU + local attention 1:2.

38L d_model=4096 16H (MQA kv=1, d_head=256) d_ff=12288 vocab=256000,
window 2048, pattern (rec, rec, attn) with a 2-layer remainder.
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_head=256,
        d_ff=12288, vocab_size=256000,
        block_pattern=("rec", "rec", "attn"), rnn_width=4096, window=2048,
        ffn_type="swiglu", norm_type="rmsnorm", tie_embeddings=True,
    ).replace(**overrides)
