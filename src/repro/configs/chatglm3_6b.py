"""chatglm3-6b [arXiv:2406.12793].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024. 2d-RoPE is
realized as partial rotary (rotary_pct=0.5), see DESIGN.md.
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_head=128,
        d_ff=13696, vocab_size=65024, rotary_pct=0.5,
        ffn_type="swiglu", norm_type="rmsnorm",
    ).replace(**overrides)
