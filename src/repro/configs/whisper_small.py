"""whisper-small [arXiv:2212.04356] — enc-dec; conv frontend STUB.

12+12L d_model=768 12H d_ff=3072 vocab=51865. input_specs() provides
precomputed frame embeddings [B, 1500, d_model] (the conv stem output).
Positional: sinusoidal (no RoPE -> rotary_pct=0).
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        is_encoder_decoder=True, n_encoder_layers=12,
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
        d_ff=3072, vocab_size=51865, rotary_pct=0.0,
        frontend="frames", encoder_len=1500,
        ffn_type="gelu", norm_type="layernorm", tie_embeddings=True,
    ).replace(**overrides)
