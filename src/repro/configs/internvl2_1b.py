"""internvl2-1b [arXiv:2404.16821] — InternViT frontend (STUB) + LM backbone.

Backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The ViT frontend is a stub per assignment: input_specs() provides
precomputed patch embeddings [B, 256, d_model].
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
        d_ff=4864, vocab_size=151655,
        frontend="patch", n_patch_tokens=256,
        ffn_type="swiglu", norm_type="rmsnorm", tie_embeddings=True,
    ).replace(**overrides)
