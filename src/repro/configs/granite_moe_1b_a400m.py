"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert, MoE 32 experts top-8,
vocab 49155.
"""
from repro.models.config import ModelConfig


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
        d_ff=512, vocab_size=49155,
        n_experts=32, top_k=8,
        ffn_type="swiglu", norm_type="rmsnorm", tie_embeddings=True,
    ).replace(**overrides)
