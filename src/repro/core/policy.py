"""MCAPolicy: where/how Monte-Carlo projection runs inside a model.

``mca_project`` is the single entry point models use for any projection
that has an a-priori importance signal (attention colmax, router prob, ...).
It implements the full paper pipeline:

    importance -> Eq.9 r schedule -> tier quantization -> capacity routing
               -> block-sampled matmuls (per tier)      [mode="tiered"]
               -> per-token i.i.d. estimator            [mode="per_token"]

and returns (y, stats) where stats carries the paper's FLOPs accounting.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.obs import devtel

from . import amm, dispatch, schedule

Stats = dict


@dataclasses.dataclass(frozen=True)
class MCAConfig:
    """User-facing MCA knobs. ``alpha`` is the paper's single error knob."""
    enabled: bool = False
    alpha: float = 0.2
    block: int = 128
    n_tiers: int = 4
    r_min_blocks: int = 1
    mode: str = "tiered"            # "tiered" | "per_token"
    # static capacity fractions (of token count) per tier, cheap->exact;
    # tier 0 is always unbounded. Calibrate per workload (benchmarks do).
    capacity_fracs: Tuple[float, ...] = (1.0, 0.5, 0.375, 0.25)
    sites: Tuple[str, ...] = ("v_proj", "o_proj")
    use_kernel: bool = False        # route per-tier matmuls to Pallas kernel
    fast_colmax: bool = False       # fuse a conservative colmax into the
                                    # lse pass (saves one O(S^2) sweep;
                                    # over-allocates samples, bound intact)

    def active(self, site: str) -> bool:
        return self.enabled and site in self.sites

    def block_for(self, d: int) -> int:
        b = min(self.block, d)
        while d % b != 0:
            b //= 2
        return max(b, 1)


def _caps_for(n_tokens: int, n_tiers: int, fracs: Tuple[float, ...]) -> Tuple[int, ...]:
    caps = []
    for t in range(n_tiers):
        if t == 0:
            caps.append(n_tokens)
        else:
            frac = fracs[min(t, len(fracs) - 1)]
            caps.append(max(1, int(round(frac * n_tokens))))
    return tuple(caps)


def exact_project(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def mca_project(key: Optional[jax.Array], x: jax.Array, w: jax.Array,
                importance: Optional[jax.Array], seq_len: int,
                cfg: MCAConfig, site: str,
                matmul_impl: Optional[Callable] = None
                ) -> Tuple[jax.Array, Stats]:
    """Project ``x @ w`` under the MCA policy.

    x: [..., n, d] (leading dims flattened internally)
    w: [d, f]
    importance: [..., n] non-negative (attention colmax / router prob);
        None or inactive site -> exact matmul.
    seq_len: the ``n`` of Eq. 9 (sequence length of the attention matrix).
    """
    lead = x.shape[:-2]
    n, d = x.shape[-2], x.shape[-1]
    f = w.shape[-1]
    flat_n = math.prod(lead) * n
    exact_fl = amm.exact_flops(flat_n, d, f)

    if not cfg.active(site) or importance is None or key is None:
        y = exact_project(x, w)
        return y, {"site": site, "exact_flops": exact_fl,
                   "mca_flops": exact_fl, "tokens": flat_n}

    block = cfg.block_for(d)
    k = d // block
    ladder = schedule.tier_ladder(d, block, cfg.n_tiers, cfg.r_min_blocks)

    x2 = x.reshape(flat_n, d)
    imp = importance.reshape(flat_n)
    r_cols = schedule.r_cols_from_attention(imp, seq_len, cfg.alpha, d)
    r_blocks = schedule.r_blocks_from_cols(r_cols, block)
    tier = schedule.assign_tiers(r_blocks, ladder)

    if cfg.mode == "per_token":
        y2 = dispatch.per_token_mca_matmul(key, x2, w, r_blocks, block)
        mca_fl = amm.sampled_flops(r_blocks, f, block)
        hist = dispatch.tier_histogram(tier, len(ladder))
    else:
        y2, hist = _tiered_maybe_sharded(key, x2, w, tier, imp, ladder,
                                         cfg, block)
        ladder_arr = jnp.asarray(ladder, jnp.int32)
        mca_fl = jnp.sum(hist * 2 * ladder_arr * block * f)

    y = y2.reshape(*lead, n, f)
    # Device-side tier occupancy: emitted once per *execution* (vs the
    # stats pytree, which the host reads once per step) so a decode scan
    # accumulates every iteration's routing. No-op unless devtel enabled.
    devtel.emit_vec(
        tuple(f"mca.device_tier_hist.t{i}" for i in range(len(ladder))),
        hist)
    stats = {"site": site, "exact_flops": exact_fl, "mca_flops": mca_fl,
             "tokens": flat_n, "tier_hist": hist,
             "mean_r_blocks": jnp.mean(r_blocks.astype(jnp.float32)),
             "ladder": ladder}
    return y, stats


def _tiered_maybe_sharded(key, x2, w, tier, imp, ladder, cfg, block):
    """Tiered dispatch, shard-local under a mesh.

    Capacity routing sorts tokens by importance; a *global* sort over a
    sharded token axis lowers to giant collectives, so under a mesh each
    shard routes its own tokens with local capacities (exactly like the
    MoE dispatch) inside shard_map.  Statistics are psum'd back.
    """
    from repro.dist.context import dp_axes, get_mesh
    n_tiers = len(ladder)
    mesh = get_mesh()
    flat_n = x2.shape[0]
    if mesh is not None and mesh.size > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        axes = tuple(a for a in mesh.axis_names)
        n_all = mesh.size
        if flat_n % n_all == 0:
            caps = _caps_for(flat_n // n_all, n_tiers, cfg.capacity_fracs)

            def local(x_l, tier_l, imp_l, key_l, w_l):
                # key enters replicated (spec P()); fold the shard's linear
                # index in so each shard draws independent block samples —
                # otherwise estimator errors are perfectly correlated along
                # the token axis and variance does not shrink with mesh size.
                lin = 0
                for a in axes:
                    lin = lin * mesh.shape[a] + jax.lax.axis_index(a)
                key_l = jax.random.fold_in(key_l, lin)
                tier_r = dispatch.apply_capacity(tier_l, imp_l, caps)
                y_l = dispatch.tiered_mca_matmul(
                    key_l, x_l, w_l, tier_r, imp_l, ladder, caps, block,
                    use_kernel=cfg.use_kernel)
                h_l = dispatch.tier_histogram(tier_r, n_tiers)
                return y_l, jax.lax.psum(h_l, axes)

            spec = P(axes)
            y2, hist = shard_map(
                local, mesh=mesh,
                in_specs=(spec, spec, spec, P(), P()),
                out_specs=(spec, P()), check_rep=False)(
                    x2, tier, imp, key, w)
            return y2, hist

    caps = _caps_for(flat_n, n_tiers, cfg.capacity_fracs)
    tier_routed = dispatch.apply_capacity(tier, imp, caps)
    y2 = dispatch.tiered_mca_matmul(key, x2, w, tier_routed, imp, ladder,
                                    caps, block, use_kernel=cfg.use_kernel)
    return y2, dispatch.tier_histogram(tier_routed, n_tiers)


def merge_stats(stats_list) -> Stats:
    """Aggregate FLOPs accounting across sites/layers."""
    out = {"exact_flops": 0, "mca_flops": 0}
    for s in stats_list:
        out["exact_flops"] = out["exact_flops"] + s["exact_flops"]
        out["mca_flops"] = out["mca_flops"] + s["mca_flops"]
    return out


def flops_reduction(stats: Stats) -> jax.Array:
    """The paper's headline metric: exact / MCA attention-encoding FLOPs."""
    return stats["exact_flops"] / jnp.maximum(stats["mca_flops"], 1)
