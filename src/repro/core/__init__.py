"""Monte-Carlo Attention core: the paper's contribution as composable JAX ops."""
from .amm import (DEFAULT_BLOCK, block_probs, block_sq_norms,
                  draw_block_samples, exact_flops, mc_matmul, num_blocks,
                  sampled_flops, sampled_matmul)
from .dispatch import (apply_capacity, per_token_mca_matmul, tier_histogram,
                       tiered_mca_matmul)
from .error_bounds import (beta_of, lemma1_bound, theorem2_mean_bound,
                           theorem2_tail_bound, w_fro)
from .policy import (MCAConfig, exact_project, flops_reduction, mca_project,
                     merge_stats)
from .schedule import (assign_tiers, importance_from_attention,
                       r_blocks_from_cols, r_cols_from_attention, tier_ladder)

__all__ = [
    "DEFAULT_BLOCK", "MCAConfig", "apply_capacity", "assign_tiers",
    "beta_of", "block_probs", "block_sq_norms", "draw_block_samples",
    "exact_flops", "exact_project", "flops_reduction",
    "importance_from_attention", "lemma1_bound", "mc_matmul", "mca_project",
    "merge_stats", "num_blocks", "per_token_mca_matmul",
    "r_blocks_from_cols", "r_cols_from_attention", "sampled_flops",
    "sampled_matmul", "theorem2_mean_bound", "theorem2_tail_bound",
    "tier_histogram", "tier_ladder", "tiered_mca_matmul", "w_fro",
]
