"""Per-token sample schedules: Eq. (9) of the paper + the TPU tier ladder.

Paper:  sqrt(r_j) = n * max(A[:, j]) / alpha   (r_j in *columns*, <= d).
TPU:    quantize r_j onto a geometric ladder of block counts
        R_t in {r_min, 2 r_min, ..., K} (K = d/block; top tier == exact),
        then route tokens to tiers like an MoE routes tokens to experts.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .amm import DEFAULT_BLOCK, num_blocks


def r_cols_from_attention(colmax: jax.Array, n: int, alpha: float,
                          d: int) -> jax.Array:
    """Eq. (9): r_j = (n * max_i A[i,j] / alpha)^2, clipped to [1, d].

    colmax: [..., n] column max of the attention matrix (>=0, <=1).
    Returns float r in columns (not yet block-quantized).
    """
    sqrt_r = (n * colmax) / alpha
    r = jnp.square(sqrt_r)
    return jnp.clip(r, 1.0, float(d))


def r_blocks_from_cols(r_cols: jax.Array, block: int = DEFAULT_BLOCK
                       ) -> jax.Array:
    """Ceil-quantize a column budget to whole sampled blocks (>=1)."""
    return jnp.maximum(jnp.ceil(r_cols / block), 1.0).astype(jnp.int32)


def tier_ladder(d: int, block: int = DEFAULT_BLOCK, n_tiers: int = 4,
                r_min_blocks: int = 1) -> tuple[int, ...]:
    """Geometric ladder of block counts; final tier is exact (R = K).

    Example: d=1024, block=128 -> K=8, n_tiers=4 -> (1, 2, 4, 8).
    The returned tuple is static (Python ints) so shapes stay static.
    """
    k = num_blocks(d, block)
    ladder = []
    r = max(1, min(r_min_blocks, k))
    for _ in range(n_tiers - 1):
        if r >= k:
            break
        ladder.append(r)
        r *= 2
    ladder.append(k)  # exact tier
    return tuple(ladder)


def assign_tiers(r_blocks: jax.Array, ladder: Sequence[int]) -> jax.Array:
    """Smallest tier whose budget covers r_blocks (conservative rounding).

    r_blocks: [..., n] int; ladder ascending; returns [..., n] int32 tier ids.
    """
    ladder_arr = jnp.asarray(ladder, dtype=jnp.int32)
    # tier = first index t with ladder[t] >= r_blocks
    tier = jnp.searchsorted(ladder_arr, r_blocks.astype(jnp.int32), side="left")
    return jnp.minimum(tier, len(ladder) - 1).astype(jnp.int32)


def importance_from_attention(attn: jax.Array) -> jax.Array:
    """max_i A[..., i, j] reduced over query and head axes.

    attn: [..., H, S_q, S_k] -> [..., S_k].  This is the materialized-A
    reference path; kernels/attn_colmax.py computes the same quantity in
    O(n) memory from (q, k, lse).
    """
    col = jnp.max(attn, axis=-2)            # over queries
    if col.ndim >= 2:
        col = jnp.max(col, axis=-2)         # over heads
    return col


def effective_alpha(alpha: float, delta: float = 1.0) -> float:
    """Theorem 2 tail: with prob >= 1-delta the error is alpha*beta*||W||/delta."""
    return alpha / delta
