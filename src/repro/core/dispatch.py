"""Precision routing: MoE-style capacity dispatch of tokens to sample tiers.

Mode B ("tiered"): tokens are routed to a small set of tiers, each tier is
one block-sampled matmul with a *static* sample count and *static* token
capacity, so XLA sees fixed shapes and the FLOPs savings are real wall-clock
savings on TPU.  Overflowing tokens are demoted to the next-cheaper tier in
priority order (highest attention keeps its precision); tier 0 is unbounded.

Mode A ("per_token"): the paper's exact per-token estimator (every token j
draws its own r_j samples i.i.d. with replacement).  Used as the accuracy
oracle and for paper-faithful benchmark accounting; its jnp formulation
costs one dense matmul on CPU while the *estimator* FLOPs are accounted
analytically (amm.sampled_flops), exactly like the paper counts FLOPs.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .amm import (DEFAULT_BLOCK, block_probs, draw_block_samples, num_blocks,
                  sampled_matmul)


def _rank_within_tier(tier: jax.Array, importance: jax.Array, n_tiers: int
                      ) -> jax.Array:
    """Rank of each token inside its tier, ordered by descending importance.

    Pure integer routing: gradients are stopped (the transpose of the
    importance-dependent scatter is both meaningless and unsupported for
    batched gathers on this jaxlib)."""
    importance = jax.lax.stop_gradient(importance)
    tier = jax.lax.stop_gradient(tier)
    n = tier.shape[0]
    order = jnp.argsort(-importance)                    # priority order
    tier_sorted = tier[order]
    onehot = tier_sorted[:, None] == jnp.arange(n_tiers)[None, :]
    rank_cum = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    # row-wise pick of column tier_sorted[i] via the one-hot (avoids a
    # batched gather, whose transpose is unsupported on this jaxlib)
    rank_sorted = jnp.sum(jnp.where(onehot, rank_cum, 0), axis=1)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    return rank


def apply_capacity(tier: jax.Array, importance: jax.Array,
                   caps: Sequence[int]) -> jax.Array:
    """Demote capacity overflow to the next cheaper tier (tier 0 unbounded).

    tier: [n] int32, importance: [n] (higher keeps precision first),
    caps: per-tier static capacities; caps[0] is ignored (unbounded).
    """
    n_tiers = len(caps)
    for t in range(n_tiers - 1, 0, -1):
        rank = _rank_within_tier(tier, importance, n_tiers)
        overflow = (tier == t) & (rank >= caps[t])
        tier = jnp.where(overflow, t - 1, tier)
    return tier


def tiered_mca_matmul(key: jax.Array, x: jax.Array, w: jax.Array,
                      tier: jax.Array, importance: jax.Array,
                      ladder: Sequence[int], caps: Sequence[int],
                      block: int = DEFAULT_BLOCK,
                      probs: jax.Array | None = None,
                      use_kernel: bool = False) -> jax.Array:
    """Dispatch tokens to tiers and run one sampled matmul per tier.

    x: [n, d]; w: [d, f]; tier/importance: [n]; ladder: ascending block
    counts, last entry == K means exact. caps: static per-tier capacities
    (caps[0] should be >= n). Returns [n, f].

    use_kernel routes each tier's sampled matmul to the Pallas
    scalar-prefetch kernel (kernels/mca_matmul.py) when tile shapes align;
    the jnp path is the reference/dry-run implementation with identical
    math.
    """
    n, d = x.shape
    f = w.shape[-1]
    k = num_blocks(d, block)
    n_tiers = len(ladder)
    if probs is None:
        probs = block_probs(w, block)
    tier = apply_capacity(tier, importance, caps)
    rank = _rank_within_tier(tier, importance, n_tiers)

    y = jnp.zeros((n, f), dtype=x.dtype)
    keys = jax.random.split(key, n_tiers)
    for t, r_t in enumerate(ladder):
        cap = int(caps[t])
        mask = tier == t
        fit = mask & (rank < cap)
        slot = jnp.where(fit, rank, cap)                       # trash row = cap
        buf = jnp.zeros((cap + 1, d), x.dtype).at[slot].add(
            jnp.where(fit[:, None], x, 0))
        if r_t >= k:                                           # exact tier
            out = jnp.dot(buf[:cap], w,
                          preferred_element_type=jnp.float32).astype(x.dtype)
        else:
            idx, inv_rp = draw_block_samples(keys[t], probs, int(r_t))
            if use_kernel and cap % min(128, cap) == 0 and block >= 128:
                from repro.kernels import mca_matmul as kernel_mm
                out = kernel_mm(buf[:cap], w, idx, inv_rp, block=block)
            else:
                out = sampled_matmul(buf[:cap], w, idx, inv_rp, block)
        gathered = jnp.take(out, jnp.clip(rank, 0, cap - 1), axis=0)
        y = jnp.where(fit[:, None], gathered, y)
    return y


def per_token_mca_matmul(key: jax.Array, x: jax.Array, w: jax.Array,
                         r_blocks: jax.Array, block: int = DEFAULT_BLOCK,
                         probs: jax.Array | None = None) -> jax.Array:
    """Paper-faithful per-token estimator (Mode A / oracle).

    Each token j draws r_blocks[j] i.i.d. block samples with replacement.
    Implemented via per-token multinomial counts so the jnp computation is
    one dense contraction (estimator FLOPs are accounted analytically).

    x: [n, d]; r_blocks: [n] int in [1, K]. Returns [n, f].
    """
    n, d = x.shape
    f = w.shape[-1]
    k = num_blocks(d, block)
    if probs is None:
        probs = block_probs(w, block)
    # K draws per token; token j uses only its first r_j draws.
    idx = jax.random.categorical(key, jnp.log(probs), shape=(n, k))  # [n, K]
    use = jnp.arange(k)[None, :] < r_blocks[:, None]                 # [n, K]
    onehot = (idx[:, :, None] == jnp.arange(k)[None, None, :]) & use[:, :, None]
    counts = jnp.sum(onehot.astype(jnp.float32), axis=1)             # [n, K]
    scale = counts / (r_blocks[:, None].astype(jnp.float32) * probs[None, :])
    xb = x.reshape(n, k, block)
    wb = w.reshape(k, block, f)
    out = jnp.einsum("nk,nkb,kbf->nf", scale.astype(x.dtype), xb, wb,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def tier_histogram(tier: jax.Array, n_tiers: int) -> jax.Array:
    """Token counts per tier — used for capacity calibration & FLOPs accounting."""
    return jnp.sum(tier[:, None] == jnp.arange(n_tiers)[None, :], axis=0)
