"""Approximate matrix multiplication (AMM) via Monte-Carlo block sampling.

This is the mathematical heart of the paper (Drineas-Kannan-Mahoney 2006,
as used by MCA, Kim & Ko AAAI 2022), adapted to TPU: instead of sampling
single columns of ``X`` / rows of ``W`` we sample 128-wide *blocks* so every
sampled term is an MXU-aligned dense matmul.  The estimator over a block
partition is identical in structure to the column estimator:

    X @ W = sum_b X[:, b] @ W[b]                      (b ranges over blocks)
          ~ (1/R) * sum_{k=1..R} X[:, s_k] @ W[s_k] / p(s_k)

with ``s_k ~ p`` i.i.d. with replacement.  Unbiasedness and the Lemma-1 /
Theorem-2 bounds hold verbatim with block norms (see error_bounds.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

DEFAULT_BLOCK = 128


def num_blocks(d: int, block: int = DEFAULT_BLOCK) -> int:
    if d % block != 0:
        raise ValueError(f"feature dim {d} not divisible by block {block}")
    return d // block


def block_sq_norms(w: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Per-block squared Frobenius norm of W's row-blocks.

    w: [d, f]  ->  [K] where K = d // block.
    """
    d = w.shape[0]
    k = num_blocks(d, block)
    w2 = jnp.sum(jnp.square(w.astype(jnp.float32)), axis=tuple(range(1, w.ndim)))
    return jnp.sum(w2.reshape(k, block), axis=1)


def block_probs(w: jax.Array, block: int = DEFAULT_BLOCK,
                floor: float = 1e-12) -> jax.Array:
    """Eq. (6) of the paper at block granularity: p(b) ∝ ||W[b]||_F^2.

    Depends only on the weights, so callers cache it per layer ("one-time
    process" in the paper). Returns [K] probabilities summing to 1.
    """
    from repro import resilience
    n2 = block_sq_norms(w, block)
    n2 = resilience.inject("amm.probs", n2)
    # numeric guard: a NaN/Inf block norm (overflowed weights, poisoned
    # update) must not poison the whole distribution — treat it as empty
    # and let the floor keep p strictly positive / normalizable even when
    # every block is zero (uniform fallback).
    n2 = jnp.where(jnp.isfinite(n2), n2, 0.0)
    n2 = jnp.maximum(n2, floor)
    return n2 / jnp.sum(n2)


def draw_block_samples(key: jax.Array, probs: jax.Array, r: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Draw ``r`` i.i.d. block indices with replacement from ``probs``.

    Returns (idx [r] int32, inv_rp [r] f32) where inv_rp[k] = 1/(r*p[idx[k]])
    is the estimator weight of sample k.
    """
    # guards against degenerate p handed in by callers bypassing
    # block_probs: non-finite mass becomes zero, log(0) -> -inf is fine
    # for categorical, and the estimator weight divides by a floored p so
    # a (theoretically impossible) drawn zero-probability block yields a
    # large-but-finite weight instead of inf.
    probs = jnp.where(jnp.isfinite(probs), probs, 0.0)
    idx = jax.random.categorical(key, jnp.log(probs), shape=(r,))
    inv_rp = 1.0 / (r * jnp.maximum(probs[idx], 1e-12))
    return idx.astype(jnp.int32), inv_rp.astype(jnp.float32)


def sampled_matmul(x: jax.Array, w: jax.Array, idx: jax.Array,
                   inv_rp: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Monte-Carlo estimate of ``x @ w`` from sampled blocks.

    x: [..., n, d], w: [d, f], idx: [R], inv_rp: [R]  ->  [..., n, f]

    Pure-jnp formulation (gather blocks, weighted einsum); the Pallas kernel
    in kernels/mca_matmul.py implements the same contraction with
    scalar-prefetch DMA so un-sampled blocks never leave HBM.
    """
    d = x.shape[-1]
    f = w.shape[-1]
    k = num_blocks(d, block)
    r = idx.shape[0]
    xb = x.reshape(*x.shape[:-1], k, block)          # [..., n, K, B]
    xg = jnp.take(xb, idx, axis=-2)                  # [..., n, R, B]
    wb = w.reshape(k, block, f)                      # [K, B, f]
    wg = jnp.take(wb, idx, axis=0)                   # [R, B, f]
    wg = wg * inv_rp[:, None, None].astype(w.dtype)  # fold estimator weights
    out = jnp.einsum("...nrb,rbf->...nf", xg, wg,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def exact_flops(n: int, d: int, f: int) -> int:
    """FLOPs of the exact encoding n x d @ d x f (paper baseline)."""
    return 2 * n * d * f


def sampled_flops(r_blocks: jax.Array | int, f: int,
                  block: int = DEFAULT_BLOCK) -> jax.Array | int:
    """FLOPs of the MC estimator given per-token sampled block counts.

    r_blocks: int or [n] int array of sampled-block counts per token.
    Matches the paper's accounting: only the AXW encoding term.
    """
    if isinstance(r_blocks, int):
        return 2 * r_blocks * block * f
    # float accumulation: int32 would overflow for >1e9 FLOPs
    return jnp.sum(2.0 * r_blocks.astype(jnp.float32) * block * f)


@functools.partial(jax.jit, static_argnames=("r", "block"))
def mc_matmul(key: jax.Array, x: jax.Array, w: jax.Array, r: int,
              block: int = DEFAULT_BLOCK,
              probs: jax.Array | None = None) -> jax.Array:
    """Convenience: draw samples and estimate x @ w with ``r`` blocks."""
    if probs is None:
        probs = block_probs(w, block)
    idx, inv_rp = draw_block_samples(key, probs, r)
    return sampled_matmul(x, w, idx, inv_rp, block)
