"""Theoretical error bounds of MCA (Lemma 1 / Theorem 2 of the paper).

Block-sampling note: the DKM proof of Lemma 1 only uses that the summands
{X[:,i] W[i]} partition the contraction and that p is a probability over
the partition; with 128-wide blocks the partition is coarser but the bound
is unchanged with r = number of *block* samples:

    E || H[j] - X[j]W ||  <=  ||X[j]||_2 ||W||_F / sqrt(r).

(The optimal-p proof uses p(b) ∝ ||X[:,b]||·||W[b]||; the paper deliberately
uses the W-only marginal p(b) ∝ ||W[b]||², which keeps the bound up to the
ratio max_b ||X[:,b]||/||X|| — we test the *paper's* inequality empirically
in tests/test_error_bounds.py.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lemma1_bound(x_row_norm: jax.Array, w_fro: jax.Array,
                 r: jax.Array) -> jax.Array:
    """E||H̃[j] - X[j]W||  <=  ||X[j]||_2 ||W||_F / sqrt(r_j)   (Eq. 7)."""
    return x_row_norm * w_fro / jnp.sqrt(r.astype(jnp.float32))


def theorem2_mean_bound(alpha: float, beta: jax.Array,
                        w_fro: jax.Array) -> jax.Array:
    """E||Ỹ[i] - Y[i]||  <=  alpha * beta * ||W||_F   (Eq. 10).

    beta = mean_j ||X[j]||_2.  Holds when sqrt(r_j) = n max(A[:,j]) / alpha
    and A is positive (Eq. 9 schedule).
    """
    return alpha * beta * w_fro


def theorem2_tail_bound(alpha: float, beta: jax.Array, w_fro: jax.Array,
                        delta: float) -> jax.Array:
    """P(||Ỹ[i]-Y[i]|| > alpha*beta*||W||_F / delta) <= delta  (Eq. 11, Markov)."""
    return alpha * beta * w_fro / delta


def beta_of(x: jax.Array) -> jax.Array:
    """beta = (1/n) sum_j ||X[j]||_2 over the last-but-one axis."""
    return jnp.mean(jnp.linalg.norm(x.astype(jnp.float32), axis=-1), axis=-1)


def w_fro(w: jax.Array) -> jax.Array:
    return jnp.linalg.norm(w.astype(jnp.float32))
