"""AdamW with decoupled weight decay, global-norm clipping, and
scan-based microbatch gradient accumulation. No external deps."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Optional[Callable] = None       # step -> lr multiplier


def _decay_mask(path) -> bool:
    """Decay matmul weights; skip norms/biases/1-d params."""
    name = str(path[-1].key) if hasattr(path[-1], "key") else ""
    return name not in {"scale", "bias", "norm", "lam", "b_a", "b_i",
                        "a_log", "d_skip", "dt_bias", "q_norm", "k_norm",
                        "q_ln", "kv_ln", "conv_b"}


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, grad_norm).

    The clip scale is folded into the per-leaf update (not materialized as
    a clipped f32 grad tree) so the f32 cast happens at the ZeRO-sharded
    moment tensors — n_data-fold smaller than the parameter sharding.
    """
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    count = state["count"] + 1
    lr = cfg.lr * (cfg.schedule(count) if cfg.schedule else 1.0)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m, v

    flat = jax.tree_util.tree_map_with_path(
        upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm


def cosine_schedule(warmup: int, total: int, min_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return fn


def accumulate_gradients(loss_fn, params, batch, n_micro: int, key=None):
    """Split the batch into ``n_micro`` microbatches and scan-accumulate
    grads — overlaps the DP gradient collectives with compute on TPU.

    loss_fn: (params, microbatch, key) -> (loss, metrics)."""
    if n_micro == 1:
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, key)

    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(split, batch)
    gfun = jax.value_and_grad(loss_fn, has_aux=True)

    def step(carry, inp):
        gsum, lsum = carry
        mb, i = inp
        k = None if key is None else jax.random.fold_in(key, i)
        (loss, metrics), g = gfun(params, mb, k)
        gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
        return (gsum, lsum + loss), metrics

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, lsum), metrics = jax.lax.scan(
        step, (g0, jnp.zeros(())), (micro, jnp.arange(n_micro)))
    grads = jax.tree.map(lambda g: g / n_micro, gsum)
    last_metrics = jax.tree.map(lambda m: m[-1], metrics)
    return (lsum / n_micro, last_metrics), grads
