from .adamw import (AdamWConfig, accumulate_gradients, apply_updates,
                    clip_by_global_norm, cosine_schedule, global_norm,
                    init_state)
