"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_mca_matmul_fixed(x, w, idx, inv_rp, block=128):
    """Oracle for mca_matmul_fixed: weighted sum of sampled block products."""
    m, d = x.shape
    _, f = w.shape
    k = d // block
    xb = x.reshape(m, k, block)
    wb = w.reshape(k, block, f)
    xg = jnp.take(xb, idx, axis=1)                 # [m, R, B]
    wg = jnp.take(wb, idx, axis=0)                 # [R, B, f]
    out = jnp.einsum("mrb,rbf,r->mf", xg.astype(jnp.float32),
                     wg.astype(jnp.float32), inv_rp.astype(jnp.float32))
    return out.astype(x.dtype)


def ref_mca_matmul_ragged(x, w, r_tile, idx, inv_rp, block=128, block_m=128):
    """Oracle for mca_matmul_ragged: per-row-tile prefix of the sample list."""
    m, d = x.shape
    _, f = w.shape
    bm = min(block_m, m)
    outs = []
    for t in range(m // bm):
        r = int(r_tile[t])
        outs.append(ref_mca_matmul_fixed(
            x[t * bm:(t + 1) * bm], w, idx[t, :r], inv_rp[t, :r], block))
    return jnp.concatenate(outs, axis=0)


def ref_attention(q, k, v, *, scale, causal=True):
    """Materialized-A attention. q:[B,Hq,Sq,dh] k,v:[B,Hkv,Skv,dh].

    Returns (out [B,Hq,Sq,dh], lse [B,Hq,Sq] f32).
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None], s, NEG_INF)
    lse = jax.nn.logsumexp(s, axis=-1)
    a = jnp.exp(s - lse[..., None])
    out = jnp.einsum("bhqk,bhkd->bhqd", a, vr.astype(jnp.float32))
    return out.astype(q.dtype), lse


def ref_colmax(q, k, lse, *, scale, causal=True):
    """Oracle for attn_colmax: max_i exp(s_ij - lse_i), per query head."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    a = jnp.exp(s - lse[..., None])
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        a = jnp.where(mask[None, None], a, 0.0)
    return jnp.max(a, axis=2)        # over queries -> [B,Hq,Skv]
