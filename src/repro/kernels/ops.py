"""Jit'd public wrappers around the Pallas kernels.

On non-TPU backends the kernels run in ``interpret=True`` mode (the kernel
body executes as jnp on CPU), so the whole framework is testable offline
while the compiled path targets TPU VMEM/MXU tiling.

Dispatch decisions (kernel vs reference fallback) are made here on static
shapes and recorded in the ``repro.obs`` registry as
``kernels.<op>.kernel_calls`` / ``kernels.<op>.fallback_calls``.  These are
*dispatch-time* counters: under ``jax.jit`` this Python runs once per
compilation, so they count distinct traced call sites, not device launches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs

from . import attn_colmax as _colmax_mod
from . import cache_update as _cache_mod
from . import flash_attention as _flash_mod
from . import mca_matmul as _mca_mod
from . import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _count(op: str, used_kernel: bool) -> None:
    which = "kernel_calls" if used_kernel else "fallback_calls"
    obs.get_registry().counter(f"kernels.{op}.{which}").inc()


def mca_matmul(x: jax.Array, w: jax.Array, idx: jax.Array, inv_rp: jax.Array,
               *, block: int = 128, block_m: int = 128, block_f: int = 128
               ) -> jax.Array:
    """Fixed-R Monte-Carlo block-sampled matmul (one precision tier)."""
    m, d = x.shape
    f = w.shape[1]
    bm, bf = min(block_m, m), min(block_f, f)
    use_kernel = m % bm == 0 and d % block == 0 and f % bf == 0
    _count("mca_matmul", use_kernel)
    if not use_kernel:
        return _ref.ref_mca_matmul_fixed(x, w, idx, inv_rp, block)
    with obs.trace("mca_matmul"):
        return _mca_mod.mca_matmul_fixed(
            x, w, idx, inv_rp, block=block, block_m=bm, block_f=bf,
            interpret=_interpret())


def _ragged_fallback(x, w, r_tile, idx, inv_rp, block, bm):
    """Traceable oracle for the ragged kernel (masked dense gather-GEMM).

    Unlike ref.ref_mca_matmul_ragged this never concretizes r_tile, so it
    is safe inside jit; samples past r_tile[t] are masked to zero weight.
    """
    m, d = x.shape
    f = w.shape[1]
    nb = d // block
    m_tiles, r_max = idx.shape
    xb = x.reshape(m_tiles, bm, nb, block)
    wb = w.reshape(nb, block, f)
    live = jnp.arange(r_max)[None, :] < r_tile[:, None]        # [T, R]
    wgt = jnp.where(live, inv_rp.astype(jnp.float32), 0.0)
    xg = jnp.take_along_axis(xb, idx[:, None, :, None], axis=2)  # [T,bm,R,B]
    wg = wb[idx]                                                 # [T,R,B,f]
    out = jnp.einsum("tmrb,trbf,tr->tmf", xg.astype(jnp.float32),
                     wg.astype(jnp.float32), wgt)
    return out.reshape(m, f).astype(x.dtype)


def mca_matmul_ragged(x, w, r_tile, idx, inv_rp, *, block=128,
                      block_m=128, block_f=128):
    """Per-row-tile-R Monte-Carlo matmul (sorted/ragged precision).

    The row-tile size is pinned by ``r_tile``'s length: the kernel needs
    ``min(block_m, m)`` row tiles to line up with it, otherwise we fall
    back to the dense masked oracle with ``bm = m // len(r_tile)``.
    """
    m, d = x.shape
    f = w.shape[1]
    m_tiles = r_tile.shape[0]
    assert m % m_tiles == 0, (m, m_tiles)
    bm, bf = min(block_m, m), min(block_f, f)
    use_kernel = (m % bm == 0 and m // bm == m_tiles
                  and d % block == 0 and f % bf == 0)
    _count("mca_matmul_ragged", use_kernel)
    if not use_kernel:
        return _ragged_fallback(x, w, r_tile, idx, inv_rp, block,
                                m // m_tiles)
    with obs.trace("mca_matmul_ragged"):
        return _mca_mod.mca_matmul_ragged(
            x, w, r_tile, idx, inv_rp, block=block, block_m=bm,
            block_f=bf, interpret=_interpret())


def kv_slot_update(cache: jax.Array, new: jax.Array, pos: jax.Array
                   ) -> jax.Array:
    """Per-row KV-cache write: ``cache[b, pos[b]] = new[b, 0]``.

    cache: [B, S, ...]; new: [B, 1, ...] (same trailing dims); pos: [B]
    int32.  The Pallas kernel folds ``pos`` into the output BlockSpec via
    scalar prefetch (DMA writes only the B touched rows, in place through
    ``input_output_aliases``); when the flattened feature size is not
    lane-aligned the XLA scatter fallback runs instead.
    """
    b, s = cache.shape[0], cache.shape[1]
    f = 1
    for d in cache.shape[2:]:
        f *= d
    use_kernel = f % 128 == 0
    _count("kv_slot_update", use_kernel)
    if not use_kernel:
        return cache.at[jnp.arange(b), pos].set(new[:, 0])
    with obs.trace("kv_slot_update"):
        out = _cache_mod.kv_slot_update(
            cache.reshape(b, s, f), new.reshape(b, 1, f), pos,
            interpret=_interpret())
    return out.reshape(cache.shape)


def flash_attention(q, k, v, *, scale, causal=True, block_q=128, block_k=128):
    """Flash attention fwd; returns (out, lse)."""
    sq, skv = q.shape[2], k.shape[2]
    bq, bk = min(block_q, sq), min(block_k, skv)
    use_kernel = sq % bq == 0 and skv % bk == 0
    _count("flash_attention", use_kernel)
    if not use_kernel:
        return _ref.ref_attention(q, k, v, scale=scale, causal=causal)
    with obs.trace("flash_attention"):
        return _flash_mod.flash_attention(
            q, k, v, scale=scale, causal=causal, block_q=bq,
            block_k=bk, interpret=_interpret())


def attn_colmax(q, k, lse, *, scale, causal=True, block_q=128, block_k=128,
                reduce_heads=True):
    """Column max of A from (q, k, lse); optionally reduced over heads."""
    sq, skv = q.shape[2], k.shape[2]
    bq, bk = min(block_q, sq), min(block_k, skv)
    use_kernel = sq % bq == 0 and skv % bk == 0
    _count("attn_colmax", use_kernel)
    if not use_kernel:
        cm = _ref.ref_colmax(q, k, lse, scale=scale, causal=causal)
    else:
        with obs.trace("attn_colmax"):
            cm = _colmax_mod.attn_colmax(
                q, k, lse, scale=scale, causal=causal, block_q=bq,
                block_k=bk, interpret=_interpret())
    if reduce_heads:
        cm = jnp.max(cm, axis=1)        # [B, Skv]
    return cm
