"""Jit'd public wrappers around the Pallas kernels.

On non-TPU backends the kernels run in ``interpret=True`` mode (the kernel
body executes as jnp on CPU), so the whole framework is testable offline
while the compiled path targets TPU VMEM/MXU tiling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attn_colmax as _colmax_mod
from . import flash_attention as _flash_mod
from . import mca_matmul as _mca_mod
from . import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def mca_matmul(x: jax.Array, w: jax.Array, idx: jax.Array, inv_rp: jax.Array,
               *, block: int = 128, block_m: int = 128, block_f: int = 128
               ) -> jax.Array:
    """Fixed-R Monte-Carlo block-sampled matmul (one precision tier)."""
    m, d = x.shape
    use_kernel = (m % min(block_m, m) == 0 and d % block == 0
                  and w.shape[1] % min(block_f, w.shape[1]) == 0)
    if not use_kernel:
        return _ref.ref_mca_matmul_fixed(x, w, idx, inv_rp, block)
    return _mca_mod.mca_matmul_fixed(
        x, w, idx, inv_rp, block=block, block_m=block_m, block_f=block_f,
        interpret=_interpret())


def mca_matmul_ragged(x, w, r_tile, idx, inv_rp, *, block=128,
                      block_m=128, block_f=128):
    """Per-row-tile-R Monte-Carlo matmul (sorted/ragged precision)."""
    return _mca_mod.mca_matmul_ragged(
        x, w, r_tile, idx, inv_rp, block=block, block_m=block_m,
        block_f=block_f, interpret=_interpret())


def flash_attention(q, k, v, *, scale, causal=True, block_q=128, block_k=128):
    """Flash attention fwd; returns (out, lse)."""
    sq, skv = q.shape[2], k.shape[2]
    bq, bk = min(block_q, sq), min(block_k, skv)
    if sq % bq or skv % bk:
        return _ref.ref_attention(q, k, v, scale=scale, causal=causal)
    return _flash_mod.flash_attention(
        q, k, v, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, interpret=_interpret())


def attn_colmax(q, k, lse, *, scale, causal=True, block_q=128, block_k=128,
                reduce_heads=True):
    """Column max of A from (q, k, lse); optionally reduced over heads."""
    sq, skv = q.shape[2], k.shape[2]
    bq, bk = min(block_q, sq), min(block_k, skv)
    if sq % bq or skv % bk:
        cm = _ref.ref_colmax(q, k, lse, scale=scale, causal=causal)
    else:
        cm = _colmax_mod.attn_colmax(
            q, k, lse, scale=scale, causal=causal, block_q=block_q,
            block_k=block_k, interpret=_interpret())
    if reduce_heads:
        cm = jnp.max(cm, axis=1)        # [B, Skv]
    return cm
