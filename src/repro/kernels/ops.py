"""Jit'd public wrappers around the Pallas kernels.

On non-TPU backends the kernels run in ``interpret=True`` mode (the kernel
body executes as jnp on CPU), so the whole framework is testable offline
while the compiled path targets TPU VMEM/MXU tiling.

Two layers of accounting, deliberately distinct (see ROADMAP § Observability):

* **dispatch-time** — kernel-vs-fallback decisions are made here on static
  shapes and recorded as ``kernels.<op>.kernel_calls`` /
  ``kernels.<op>.fallback_calls``.  Under ``jax.jit`` this Python runs
  once per compilation, so these count *distinct traced call sites* (how
  many places in the program dispatched which path), not executions.
* **device launches** — when ``obs.devtel`` is enabled, each wrapper also
  emits per-*execution* counts: ``kernels.<op>.device_launches`` fires
  once every time the op actually runs on the device (every ``lax.scan``
  iteration of a decode burst, every call of a compiled function), plus a
  per-op work count (``device_sampled_blocks`` for the MCA matmuls —
  sampled block contributions accumulated in-kernel, so the ragged
  kernel's skipped samples are excluded; ``device_tiles`` for
  flash/colmax score tiles; ``device_rows_written`` for the KV update).
  On the kernel path the counts come from an in-kernel telemetry buffer
  (kernels/telemetry.py); on the fallback path the wrapper emits the
  analytically equivalent values, so both paths report launches the same
  way.  Telemetry is a trace-time flag: enable it *before* the first
  compilation of the code under measurement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs
from repro.obs import devtel

from . import attn_colmax as _colmax_mod
from . import cache_update as _cache_mod
from . import flash_attention as _flash_mod
from . import mca_matmul as _mca_mod
from . import ref as _ref
from .telemetry import LANE_COUNT, LANE_LAUNCH


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _count(op: str, used_kernel: bool) -> None:
    which = "kernel_calls" if used_kernel else "fallback_calls"
    obs.get_registry().counter(f"kernels.{op}.{which}").inc()


def _emit_tel(op: str, work_metric: str, launches, work) -> None:
    """Per-execution device telemetry for one op (no-op when disabled)."""
    devtel.emit_vec(
        (f"kernels.{op}.device_launches", f"kernels.{op}.{work_metric}"),
        (launches, work))


def mca_matmul(x: jax.Array, w: jax.Array, idx: jax.Array, inv_rp: jax.Array,
               *, block: int = 128, block_m: int = 128, block_f: int = 128
               ) -> jax.Array:
    """Fixed-R Monte-Carlo block-sampled matmul (one precision tier).

    Device telemetry: ``device_sampled_blocks`` counts one per
    (row tile, sample) — ``m_tiles * R`` on the kernel path; the dense
    fallback has no row tiling, so it counts the sample-list length ``R``.
    """
    m, d = x.shape
    f = w.shape[1]
    bm, bf = min(block_m, m), min(block_f, f)
    use_kernel = m % bm == 0 and d % block == 0 and f % bf == 0
    _count("mca_matmul", use_kernel)
    if not use_kernel:
        out = _ref.ref_mca_matmul_fixed(x, w, idx, inv_rp, block)
        _emit_tel("mca_matmul", "device_sampled_blocks", 1, idx.shape[0])
        return out
    with obs.trace("mca_matmul"):
        if devtel.enabled():
            out, tel = _mca_mod.mca_matmul_fixed(
                x, w, idx, inv_rp, block=block, block_m=bm, block_f=bf,
                interpret=_interpret(), telemetry=True)
            _emit_tel("mca_matmul", "device_sampled_blocks",
                      tel[0, LANE_LAUNCH], tel[0, LANE_COUNT])
            return out
        return _mca_mod.mca_matmul_fixed(
            x, w, idx, inv_rp, block=block, block_m=bm, block_f=bf,
            interpret=_interpret())


def _ragged_fallback(x, w, r_tile, idx, inv_rp, block, bm):
    """Traceable oracle for the ragged kernel (masked dense gather-GEMM).

    Unlike ref.ref_mca_matmul_ragged this never concretizes r_tile, so it
    is safe inside jit; samples past r_tile[t] are masked to zero weight.
    """
    m, d = x.shape
    f = w.shape[1]
    nb = d // block
    m_tiles, r_max = idx.shape
    xb = x.reshape(m_tiles, bm, nb, block)
    wb = w.reshape(nb, block, f)
    live = jnp.arange(r_max)[None, :] < r_tile[:, None]        # [T, R]
    wgt = jnp.where(live, inv_rp.astype(jnp.float32), 0.0)
    xg = jnp.take_along_axis(xb, idx[:, None, :, None], axis=2)  # [T,bm,R,B]
    wg = wb[idx]                                                 # [T,R,B,f]
    out = jnp.einsum("tmrb,trbf,tr->tmf", xg.astype(jnp.float32),
                     wg.astype(jnp.float32), wgt)
    return out.reshape(m, f).astype(x.dtype)


def mca_matmul_ragged(x, w, r_tile, idx, inv_rp, *, block=128,
                      block_m=128, block_f=128):
    """Per-row-tile-R Monte-Carlo matmul (sorted/ragged precision).

    The row-tile size is pinned by ``r_tile``'s length: the kernel needs
    ``min(block_m, m)`` row tiles to line up with it, otherwise we fall
    back to the dense masked oracle with ``bm = m // len(r_tile)``.

    Device telemetry: ``device_sampled_blocks == sum(r_tile)`` on both
    paths (blocks the ragged kernel actually accumulated — its
    ``pl.when`` skipping makes this the device-only truth the dispatcher
    cannot see).
    """
    m, d = x.shape
    f = w.shape[1]
    m_tiles = r_tile.shape[0]
    assert m % m_tiles == 0, (m, m_tiles)
    bm, bf = min(block_m, m), min(block_f, f)
    use_kernel = (m % bm == 0 and m // bm == m_tiles
                  and d % block == 0 and f % bf == 0)
    _count("mca_matmul_ragged", use_kernel)
    if not use_kernel:
        out = _ragged_fallback(x, w, r_tile, idx, inv_rp, block,
                               m // m_tiles)
        _emit_tel("mca_matmul_ragged", "device_sampled_blocks",
                  1, jnp.sum(r_tile))
        return out
    with obs.trace("mca_matmul_ragged"):
        if devtel.enabled():
            out, tel = _mca_mod.mca_matmul_ragged(
                x, w, r_tile, idx, inv_rp, block=block, block_m=bm,
                block_f=bf, interpret=_interpret(), telemetry=True)
            _emit_tel("mca_matmul_ragged", "device_sampled_blocks",
                      tel[0, LANE_LAUNCH], tel[0, LANE_COUNT])
            return out
        return _mca_mod.mca_matmul_ragged(
            x, w, r_tile, idx, inv_rp, block=block, block_m=bm,
            block_f=bf, interpret=_interpret())


def kv_slot_update(cache: jax.Array, new: jax.Array, pos: jax.Array
                   ) -> jax.Array:
    """Per-row KV-cache write: ``cache[b, pos[b]] = new[b, 0]``.

    cache: [B, S, ...]; new: [B, 1, ...] (same trailing dims); pos: [B]
    int32.  The Pallas kernel folds ``pos`` into the output BlockSpec via
    scalar prefetch (DMA writes only the B touched rows, in place through
    ``input_output_aliases``); when the flattened feature size is not
    lane-aligned the XLA scatter fallback runs instead.

    Device telemetry: ``device_rows_written == B`` per execution on both
    paths — a K-step decode burst therefore shows K launches where the
    dispatch counter shows one traced call site.
    """
    b, s = cache.shape[0], cache.shape[1]
    f = 1
    for d in cache.shape[2:]:
        f *= d
    use_kernel = f % 128 == 0
    _count("kv_slot_update", use_kernel)
    if not use_kernel:
        out = cache.at[jnp.arange(b), pos].set(new[:, 0])
        _emit_tel("kv_slot_update", "device_rows_written", 1, b)
        return out
    with obs.trace("kv_slot_update"):
        if devtel.enabled():
            out, tel = _cache_mod.kv_slot_update(
                cache.reshape(b, s, f), new.reshape(b, 1, f), pos,
                interpret=_interpret(), telemetry=True)
            _emit_tel("kv_slot_update", "device_rows_written",
                      tel[0, LANE_LAUNCH], tel[0, LANE_COUNT])
        else:
            out = _cache_mod.kv_slot_update(
                cache.reshape(b, s, f), new.reshape(b, 1, f), pos,
                interpret=_interpret())
    return out.reshape(cache.shape)


def flash_attention(q, k, v, *, scale, causal=True, block_q=128, block_k=128):
    """Flash attention fwd; returns (out, lse).

    Device telemetry: ``device_tiles`` counts score tiles actually
    computed in-kernel (causally skipped tiles excluded); the dense
    fallback reports 0 tiles (no tiling), launches still count 1 per
    execution.
    """
    sq, skv = q.shape[2], k.shape[2]
    bq, bk = min(block_q, sq), min(block_k, skv)
    use_kernel = sq % bq == 0 and skv % bk == 0
    _count("flash_attention", use_kernel)
    if not use_kernel:
        out = _ref.ref_attention(q, k, v, scale=scale, causal=causal)
        _emit_tel("flash_attention", "device_tiles", 1, 0)
        return out
    with obs.trace("flash_attention"):
        if devtel.enabled():
            out, lse, tel = _flash_mod.flash_attention(
                q, k, v, scale=scale, causal=causal, block_q=bq,
                block_k=bk, interpret=_interpret(), telemetry=True)
            _emit_tel("flash_attention", "device_tiles",
                      tel[0, LANE_LAUNCH], tel[0, LANE_COUNT])
            return out, lse
        return _flash_mod.flash_attention(
            q, k, v, scale=scale, causal=causal, block_q=bq,
            block_k=bk, interpret=_interpret())


def attn_colmax(q, k, lse, *, scale, causal=True, block_q=128, block_k=128,
                reduce_heads=True):
    """Column max of A from (q, k, lse); optionally reduced over heads.

    Device telemetry mirrors flash_attention: ``device_tiles`` = score
    tiles recomputed in-kernel, fallback reports (1 launch, 0 tiles).
    """
    sq, skv = q.shape[2], k.shape[2]
    bq, bk = min(block_q, sq), min(block_k, skv)
    use_kernel = sq % bq == 0 and skv % bk == 0
    _count("attn_colmax", use_kernel)
    if not use_kernel:
        cm = _ref.ref_colmax(q, k, lse, scale=scale, causal=causal)
        _emit_tel("attn_colmax", "device_tiles", 1, 0)
    else:
        with obs.trace("attn_colmax"):
            if devtel.enabled():
                cm, tel = _colmax_mod.attn_colmax(
                    q, k, lse, scale=scale, causal=causal, block_q=bq,
                    block_k=bk, interpret=_interpret(), telemetry=True)
                _emit_tel("attn_colmax", "device_tiles",
                          tel[0, LANE_LAUNCH], tel[0, LANE_COUNT])
            else:
                cm = _colmax_mod.attn_colmax(
                    q, k, lse, scale=scale, causal=causal, block_q=bq,
                    block_k=bk, interpret=_interpret())
    if reduce_heads:
        cm = jnp.max(cm, axis=1)        # [B, Skv]
    return cm
