"""In-kernel telemetry buffer conventions shared by the Pallas kernels.

Each kernel optionally emits a ``(1, TEL_WIDTH)`` int32 output block,
mapped to the same (0, 0) tile for every grid program, accumulated
in-kernel:

* lane ``LANE_LAUNCH`` — set to 1 once per kernel execution (first grid
  program), so summing across executions counts device launches;
* lane ``LANE_COUNT``  — per-op work counter (sampled blocks accumulated,
  tiles computed, rows written — see each kernel's docstring);
* remaining lanes are reserved (zero).

Because every program writes the same output tile, telemetry variants
must run with all-``"arbitrary"`` dimension semantics: Megacore may
otherwise split ``"parallel"`` grid dimensions across cores, making a
shared accumulator block unsafe on real TPUs.  The wrappers in
``kernels/ops.py`` only request telemetry when ``obs.devtel`` is enabled,
so the default path keeps its parallel semantics.

Lane ops are vector-shaped (one-hot ``(1, TEL_WIDTH)`` increments built
from ``broadcasted_iota``) rather than scalar ref stores — scalar int
stores at dynamic offsets are not reliably supported by the TPU vector
ISA, one-hot adds are.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TEL_WIDTH = 8
LANE_LAUNCH = 0
LANE_COUNT = 1


def lane_inc(lane: int):
    """One-hot ``(1, TEL_WIDTH)`` int32 increment for ``lane``."""
    return (jax.lax.broadcasted_iota(jnp.int32, (1, TEL_WIDTH), 1)
            == lane).astype(jnp.int32)


def tel_shape():
    """out_shape entry for the telemetry output."""
    return jax.ShapeDtypeStruct((1, TEL_WIDTH), jnp.int32)
