"""Pallas TPU kernel: flash attention forward (online softmax) + LSE output.

The LSE (per-row logsumexp) output is what makes MCA cheap to drive: the
attention column-max of Eq. 9 is recoverable from (q, k, lse) in O(n) memory
by the companion kernel in attn_colmax.py — A is never materialized.

Supports GQA natively: kv tensors keep their own head count and the
BlockSpec index_map maps query head h -> kv head h // (Hq // Hkv), so
repeated KV never exists in memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .mca_matmul import _compiler_params
from .telemetry import LANE_COUNT, LANE_LAUNCH, lane_inc, tel_shape

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                  *rest, scale, causal, bq, bk, nk, off):
    if len(rest) == 4:                    # telemetry output precedes scratch
        tel_ref, acc_ref, m_ref, l_ref = rest
    else:
        tel_ref = None
        acc_ref, m_ref, l_ref = rest
    bb = pl.program_id(0)
    h = pl.program_id(1)
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if tel_ref is not None:
        @pl.when((bb == 0) & (h == 0) & (i == 0) & (j == 0))
        def _tel_init():
            tel_ref[...] = lane_inc(LANE_LAUNCH)

    def _compute():
        if tel_ref is not None:
            tel_ref[...] += lane_inc(LANE_COUNT)   # score tiles computed
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)                # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            # diagonal offset skv - sq: query i sees keys j <= i + off
            # (matches ref_attention's jnp.tril(..., k=skv - sq))
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows + off >= cols, s, NEG_INF)
        m_prev = m_ref[...]                                # [bq, 1]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # [bq, bk]
        corr = jnp.exp(m_prev - m_new)                     # [bq, 1]
        l_ref[...] = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                # [bk, dh]
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    if causal:
        # skip tiles that are entirely above the (offset) diagonal
        pl.when(j * bk <= i * bq + bq - 1 + off)(_compute)
    else:
        _compute()

    @pl.when(j == nk - 1)
    def _done():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(safe_l))[:, 0].astype(
            lse_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q",
                                             "block_k", "interpret",
                                             "telemetry"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False,
                    telemetry: bool = False):
    """q: [B, Hq, Sq, dh]; k, v: [B, Hkv, Skv, dh]; Hq % Hkv == 0.

    Returns (out [B, Hq, Sq, dh], lse [B, Hq, Sq] float32) — plus a
    ``(1, TEL_WIDTH)`` int32 telemetry buffer (lane 0 = 1 launch, lane 1 =
    score tiles actually computed, i.e. causal skipping excluded) when
    ``telemetry=True``; the telemetry variant runs all-"arbitrary"
    semantics so the shared tile is Megacore-safe.
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    nq, nk = sq // bq, skv // bk

    grid = (b, hq, nq, nk)
    kv_map = lambda bb, h, i, j: (bb, h // group, j, 0)
    out_specs = [
        pl.BlockSpec((1, 1, bq, dh), lambda bb, h, i, j: (bb, h, i, 0)),
        pl.BlockSpec((1, 1, bq), lambda bb, h, i, j: (bb, h, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
    ]
    semantics = ("parallel", "parallel", "parallel", "arbitrary")
    if telemetry:
        out_specs.append(pl.BlockSpec((1, tel_shape().shape[1]),
                                      lambda bb, h, i, j: (0, 0)))
        out_shape.append(tel_shape())
        semantics = ("arbitrary",) * 4
    fn = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk, off=skv - sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), kv_map),
            pl.BlockSpec((1, 1, bk, dh), kv_map),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_compiler_params(semantics),
        interpret=interpret,
    )
    return fn(q, k, v)
