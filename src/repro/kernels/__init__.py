"""Pallas TPU kernels for the MCA hot loops (interpret-mode on CPU).

The paper's own CUDA kernel is a fused gather-GEMM for the sampled
projection; kernels here are its TPU-native counterparts (see DESIGN.md):
  mca_matmul      block-sampled matmul, scalar-prefetch DMA gather
  flash_attention online-softmax fwd producing LSE (the colmax enabler)
  attn_colmax     Eq.9 r-driver: max_i A[i,j] in O(n) memory
  kv_slot_update  per-row KV-cache write (per-slot continuous batching)
"""
from .ops import (attn_colmax, flash_attention, kv_slot_update,
                  mca_matmul, mca_matmul_ragged)

__all__ = ["attn_colmax", "flash_attention", "kv_slot_update",
           "mca_matmul", "mca_matmul_ragged"]
