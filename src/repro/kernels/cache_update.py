"""Pallas TPU kernel: slot-sliced KV-cache update for per-slot decoding.

Writes one new KV row per batch row at a *per-row* cache position::

    cache[b, pos[b]] = new[b, 0]          for every b

This is the decode-side primitive of per-slot continuous batching: every
decode slot advances at its own sequence position, so the classic
``dynamic_update_slice`` (one shared position for the whole batch) no
longer applies.  A naive ``cache.at[arange(B), pos].set(...)`` lowers to a
general scatter; this kernel instead folds the per-row position into the
output BlockSpec ``index_map`` via scalar prefetch, so the DMA engine
writes ONLY the B touched rows — the untouched cache slots are never read
or copied (``input_output_aliases`` makes the donated cache buffer the
output buffer).

Grid is one program per batch row; the kernel body is a pure VMEM copy of
the [1, F] new row.  The cache operand is declared ``memory_space=ANY``
and never dereferenced — it exists only to donate its buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .telemetry import LANE_COUNT, LANE_LAUNCH, lane_inc, tel_shape


def _kernel(pos_ref, new_ref, cache_ref, out_ref, *tel):
    del pos_ref, cache_ref          # consumed by the index_map / aliasing
    out_ref[...] = new_ref[...]
    if tel:
        (tel_ref,) = tel
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _tel_init():
            tel_ref[...] = lane_inc(LANE_LAUNCH)

        tel_ref[...] += lane_inc(LANE_COUNT)      # one row written per program


@functools.partial(jax.jit, static_argnames=("interpret", "telemetry"))
def kv_slot_update(cache: jax.Array, new: jax.Array, pos: jax.Array,
                   *, interpret: bool = False, telemetry: bool = False):
    """cache: [B, S, F]; new: [B, 1, F]; pos: [B] int32 -> updated cache.

    Rows with ``pos[b]`` outside [0, S) are clamped by the BlockSpec index
    math on TPU; callers must pass in-range positions (the serve engine's
    admission control guarantees it).

    With ``telemetry=True`` returns ``(cache, tel)`` where the
    ``(1, TEL_WIDTH)`` int32 buffer holds lane 0 = 1 launch, lane 1 = B
    rows written (accumulated in-kernel; the 1-D grid is sequential, so
    the shared telemetry tile needs no semantics override).
    """
    b, s, f = cache.shape
    assert new.shape == (b, 1, f), (new.shape, cache.shape)
    out_specs = pl.BlockSpec((1, 1, f), lambda i, pos: (i, pos[i], 0))
    out_shape = jax.ShapeDtypeStruct(cache.shape, cache.dtype)
    if telemetry:
        out_specs = [out_specs,
                     pl.BlockSpec((1, tel_shape().shape[1]),
                                  lambda i, pos: (0, 0))]
        out_shape = [out_shape, tel_shape()]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                       # pos
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, 1, f), lambda i, pos: (i, 0, 0)),     # new
            pl.BlockSpec(memory_space=pltpu.ANY),                  # cache
        ],
        out_specs=out_specs,
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases={2: 0},                 # cache buffer -> out
        interpret=interpret,
    )
    return fn(pos.astype(jnp.int32), new, cache)
