"""Pallas TPU kernel: attention column-max from (q, k, lse) in O(n) memory.

colmax[j] = max_i A[i, j] = max_i exp(q_i . k_j * scale - lse_i)

This is the r-schedule driver of MCA (Eq. 9).  Materializing A to take a
column max would cost O(n^2) memory and defeat flash attention; instead we
recompute score tiles (like a flash backward pass does) and fold the max.
Output is per query-head; the ops wrapper reduces over heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .mca_matmul import _compiler_params
from .telemetry import LANE_COUNT, LANE_LAUNCH, lane_inc, tel_shape


def _colmax_kernel(q_ref, k_ref, lse_ref, o_ref, *rest,
                   scale, causal, bq, bk, nq, off):
    if len(rest) == 2:                    # telemetry output precedes scratch
        tel_ref, cm_ref = rest
    else:
        tel_ref, (cm_ref,) = None, rest
    bb = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)   # kv tile
    i = pl.program_id(3)   # q tile (innermost)

    @pl.when(i == 0)
    def _init():
        cm_ref[...] = jnp.zeros_like(cm_ref)

    if tel_ref is not None:
        @pl.when((bb == 0) & (h == 0) & (j == 0) & (i == 0))
        def _tel_init():
            tel_ref[...] = lane_inc(LANE_LAUNCH)

    def _compute():
        if tel_ref is not None:
            tel_ref[...] += lane_inc(LANE_COUNT)   # score tiles recomputed
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        lse = lse_ref[0, 0][:, None]                         # [bq, 1]
        a = jnp.exp(s - lse)                                 # [bq, bk]
        if causal:
            # diagonal offset skv - sq, as in ref_colmax's tril(k=skv - sq)
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            a = jnp.where(rows + off >= cols, a, 0.0)
        cm_ref[...] = jnp.maximum(cm_ref[...],
                                  jnp.max(a, axis=0, keepdims=True))

    if causal:
        # q tiles strictly above the (offset) kv tile see nothing of it
        pl.when(i * bq + bq - 1 + off >= j * bk)(_compute)
    else:
        _compute()

    @pl.when(i == nq - 1)
    def _done():
        o_ref[0, 0] = cm_ref[...][0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q",
                                             "block_k", "interpret",
                                             "telemetry"))
def attn_colmax(q: jax.Array, k: jax.Array, lse: jax.Array, *, scale: float,
                causal: bool = True, block_q: int = 128, block_k: int = 128,
                interpret: bool = False, telemetry: bool = False):
    """q: [B, Hq, Sq, dh]; k: [B, Hkv, Skv, dh]; lse: [B, Hq, Sq] (from
    flash_attention).  Returns colmax [B, Hq, Skv] float32 — or
    ``(colmax, tel)`` with ``telemetry=True`` (lane 0 = 1 launch, lane 1 =
    score tiles recomputed; all-"arbitrary" semantics, see
    kernels/telemetry.py).
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0
    nq, nk = sq // bq, skv // bk

    grid = (b, hq, nk, nq)
    out_specs = pl.BlockSpec((1, 1, bk), lambda bb, h, j, i: (bb, h, j))
    out_shape = jax.ShapeDtypeStruct((b, hq, skv), jnp.float32)
    semantics = ("parallel", "parallel", "parallel", "arbitrary")
    if telemetry:
        out_specs = [out_specs,
                     pl.BlockSpec((1, tel_shape().shape[1]),
                                  lambda bb, h, j, i: (0, 0))]
        out_shape = [out_shape, tel_shape()]
        semantics = ("arbitrary",) * 4
    fn = pl.pallas_call(
        functools.partial(_colmax_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nq=nq, off=skv - sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda bb, h, j, i: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda bb, h, j, i: (bb, h // group, j, 0)),
            pl.BlockSpec((1, 1, bq), lambda bb, h, j, i: (bb, h, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((1, bk), jnp.float32)],
        compiler_params=_compiler_params(semantics),
        interpret=interpret,
    )
    return fn(q, k, lse)
