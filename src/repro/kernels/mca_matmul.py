"""Pallas TPU kernel: Monte-Carlo block-sampled matmul (the MCA hot loop).

Computes   o = sum_k inv_rp[k] * x[:, s[k]*B:(s[k]+1)*B] @ w[s[k]*B:(s[k]+1)*B, :]

The sampled block indices ``s`` live in SMEM via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``): the BlockSpec ``index_map`` of ``x`` and
``w`` reads ``s[k]`` so the DMA engine streams ONLY the sampled blocks
HBM->VMEM.  The gather is folded into the address computation of the
double-buffered pipeline — zero extra cost over a dense matmul of the same
sampled size.  This is the TPU-native analogue of the paper's fused
gather-GEMM CUDA kernel.

Two variants:
  * ``mca_matmul_fixed``  — one sample list for all rows (one tier).
  * ``mca_matmul_ragged`` — per-row-tile sample counts r_tile[i]; compute
    for k >= r_tile[i] is skipped with ``pl.when`` (MXU work saved; the
    prefetch index is clamped so the DMA re-reads the previous block, which
    the pipeline coalesces).

With ``telemetry=True`` both variants return ``(out, tel)`` where ``tel``
is a ``(1, TEL_WIDTH)`` int32 buffer accumulated in-kernel (see
kernels/telemetry.py): lane 0 = 1 launch, lane 1 = sampled block
contributions actually accumulated — ``m_tiles * r`` for fixed,
``sum(r_tile)`` for ragged (the ragged skip makes this device-only
truth).  Telemetry runs with all-"arbitrary" grid semantics so the shared
accumulator tile is Megacore-safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .telemetry import LANE_COUNT, LANE_LAUNCH, lane_inc, tel_shape

DEFAULT_BLOCK = 128  # sampled column-block width (lane-aligned)


def _compiler_params(dimension_semantics):
    cp = getattr(pltpu, "CompilerParams", None)
    if cp is None:  # older jax
        cp = getattr(pltpu, "TPUCompilerParams")
    return cp(dimension_semantics=dimension_semantics)


# ---------------------------------------------------------------- fixed R
def _fixed_kernel(s_ref, scale_ref, x_ref, w_ref, o_ref, *rest, n_samples):
    if len(rest) == 2:                    # telemetry output precedes scratch
        tel_ref, acc_ref = rest
    else:
        tel_ref, (acc_ref,) = None, rest
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if tel_ref is not None:
        @pl.when((i == 0) & (j == 0) & (k == 0))
        def _tel_init():
            tel_ref[...] = lane_inc(LANE_LAUNCH)

        @pl.when(j == 0)                  # one count per (row tile, sample)
        def _tel_count():
            tel_ref[...] += lane_inc(LANE_COUNT)

    xb = x_ref[...]                       # [bm, B]
    wb = w_ref[...]                       # [B, bf]
    contrib = jnp.dot(xb, wb, preferred_element_type=jnp.float32)
    acc_ref[...] += scale_ref[k] * contrib

    @pl.when(k == n_samples - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "block_m", "block_f",
                                             "interpret", "telemetry"))
def mca_matmul_fixed(x: jax.Array, w: jax.Array, idx: jax.Array,
                     inv_rp: jax.Array, *, block: int = DEFAULT_BLOCK,
                     block_m: int = 128, block_f: int = 128,
                     interpret: bool = False, telemetry: bool = False):
    """x: [m, d], w: [d, f], idx: [R] int32 block ids, inv_rp: [R] f32.

    Returns ``out`` — or ``(out, tel)`` with ``telemetry=True`` where
    ``tel[0, LANE_COUNT] == m_tiles * r`` (see module docstring).
    """
    m, d = x.shape
    d2, f = w.shape
    assert d == d2 and d % block == 0
    r = idx.shape[0]
    bm = min(block_m, m)
    bf = min(block_f, f)
    assert m % bm == 0 and f % bf == 0, (m, bm, f, bf)

    grid = (m // bm, f // bf, r)
    out_specs = pl.BlockSpec((bm, bf), lambda i, j, k, s, sc: (i, j))
    out_shape = jax.ShapeDtypeStruct((m, f), x.dtype)
    semantics = ("parallel", "parallel", "arbitrary")
    if telemetry:
        out_specs = [out_specs,
                     pl.BlockSpec((1, tel_shape().shape[1]),
                                  lambda i, j, k, s, sc: (0, 0))]
        out_shape = [out_shape, tel_shape()]
        semantics = ("arbitrary", "arbitrary", "arbitrary")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # idx, inv_rp
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, block), lambda i, j, k, s, sc: (i, s[k])),
            pl.BlockSpec((block, bf), lambda i, j, k, s, sc: (s[k], j)),
        ],
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((bm, bf), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_fixed_kernel, n_samples=r),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_compiler_params(semantics),
        interpret=interpret,
    )
    return fn(idx.astype(jnp.int32), inv_rp.astype(jnp.float32), x, w)


# --------------------------------------------------------------- ragged R
def _ragged_kernel(r_ref, s_ref, scale_ref, x_ref, w_ref, o_ref, *rest,
                   n_samples):
    if len(rest) == 2:
        tel_ref, acc_ref = rest
    else:
        tel_ref, (acc_ref,) = None, rest
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if tel_ref is not None:
        @pl.when((i == 0) & (j == 0) & (k == 0))
        def _tel_init():
            tel_ref[...] = lane_inc(LANE_LAUNCH)

        @pl.when((j == 0) & (k < r_ref[i]))   # only blocks actually used
        def _tel_count():
            tel_ref[...] += lane_inc(LANE_COUNT)

    @pl.when(k < r_ref[i])
    def _accum():
        contrib = jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)
        acc_ref[...] += scale_ref[i, k] * contrib

    @pl.when(k == n_samples - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "block_m", "block_f",
                                             "interpret", "telemetry"))
def mca_matmul_ragged(x: jax.Array, w: jax.Array, r_tile: jax.Array,
                      idx: jax.Array, inv_rp: jax.Array, *,
                      block: int = DEFAULT_BLOCK, block_m: int = 128,
                      block_f: int = 128, interpret: bool = False,
                      telemetry: bool = False):
    """Per-row-tile sample counts.

    x: [m, d]; w: [d, f]; r_tile: [m_tiles] int32 (1..R_max);
    idx: [m_tiles, R_max] block ids; inv_rp: [m_tiles, R_max] f32 weights
    (already contain the 1/(r_i * p) factor; entries past r_tile are unused).
    Returns ``out`` — or ``(out, tel)`` with ``telemetry=True`` where
    ``tel[0, LANE_COUNT] == sum(r_tile)``.
    """
    m, d = x.shape
    _, f = w.shape
    bm = min(block_m, m)
    bf = min(block_f, f)
    assert m % bm == 0 and f % bf == 0
    m_tiles = m // bm
    assert r_tile.shape == (m_tiles,), (r_tile.shape, m_tiles)
    r_max = idx.shape[1]

    grid = (m_tiles, f // bf, r_max)

    def x_map(i, j, k, r, s, sc):
        kk = jnp.minimum(k, r[i] - 1)     # clamp: re-read last needed block
        return (i, s[i, kk])

    def w_map(i, j, k, r, s, sc):
        kk = jnp.minimum(k, r[i] - 1)
        return (s[i, kk], j)

    out_specs = pl.BlockSpec((bm, bf), lambda i, j, k, r, s, sc: (i, j))
    out_shape = jax.ShapeDtypeStruct((m, f), x.dtype)
    semantics = ("parallel", "parallel", "arbitrary")
    if telemetry:
        out_specs = [out_specs,
                     pl.BlockSpec((1, tel_shape().shape[1]),
                                  lambda i, j, k, r, s, sc: (0, 0))]
        out_shape = [out_shape, tel_shape()]
        semantics = ("arbitrary", "arbitrary", "arbitrary")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # r_tile, idx, inv_rp
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, block), x_map),
            pl.BlockSpec((block, bf), w_map),
        ],
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((bm, bf), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_ragged_kernel, n_samples=r_max),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_compiler_params(semantics),
        interpret=interpret,
    )
    return fn(r_tile.astype(jnp.int32), idx.astype(jnp.int32),
              inv_rp.astype(jnp.float32), x, w)
