"""Span timelines: request-scoped tracing exported as Chrome-trace JSON.

A *span* is a named host-side time interval (``time.perf_counter``
stamps) with a category, a *track* (one timeline row — e.g.
``serve.per_slot/req3`` follows one request end-to-end), and free-form
``args``.  Spans are recorded into the current :class:`~.registry.Registry`
(so ``obs.scoped()`` isolation applies) and exported with
:func:`export_chrome_trace` as Chrome trace-event JSON that loads in
``chrome://tracing`` or https://ui.perfetto.dev.

Tracing is **off by default** and :func:`span` / :func:`record_span` are
zero-overhead no-ops while disabled: no registry writes, no object
allocation beyond the flag check, safe to call inside ``jit``-traced
Python.  Enable with :func:`enable_tracing` (process-wide) or the
:func:`tracing` context manager (tests, ``benchmarks.run --trace-out``).

All spans share the ``perf_counter`` clock; a request chain looks like::

    queue → prefill → decode (one per burst) → finish

on the track ``<cat>/req<uid>`` where ``<cat>`` is ``serve.wave``
(``ContinuousBatcher``) or ``serve.per_slot`` (``SlotBatcher``).
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Dict, Iterator, Mapping, Optional

from .registry import Registry, get_registry

_enabled = False
_NULL = contextlib.nullcontext()


def enable_tracing(flag: bool = True) -> None:
    """Globally enable/disable span recording."""
    global _enabled
    _enabled = bool(flag)


def tracing_enabled() -> bool:
    return _enabled


@contextlib.contextmanager
def tracing(flag: bool = True) -> Iterator[None]:
    """Temporarily flip span recording (restores the prior state)."""
    global _enabled
    prev = _enabled
    _enabled = bool(flag)
    try:
        yield
    finally:
        _enabled = prev


def record_span(
    name: str,
    t0: float,
    t1: float,
    cat: str = "",
    track: str = "",
    args: Optional[Mapping[str, Any]] = None,
    registry: Optional[Registry] = None,
) -> None:
    """Record a completed span [t0, t1] (``perf_counter`` seconds).

    No-op while tracing is disabled. ``t0 == t1`` records an instant
    marker (e.g. a request's terminal ``finish`` event).
    """
    if not _enabled:
        return
    reg = registry if registry is not None else get_registry()
    reg.add_span(
        {
            "name": name,
            "cat": cat,
            "track": track or cat or "main",
            "ts": float(t0),
            "dur": max(float(t1) - float(t0), 0.0),
            "args": dict(args) if args else {},
        }
    )


def mark(
    name: str,
    cat: str = "",
    track: str = "",
    args: Optional[Mapping[str, Any]] = None,
    registry: Optional[Registry] = None,
) -> None:
    """Record an instant (zero-duration) span at the current time."""
    t = time.perf_counter()
    record_span(name, t, t, cat=cat, track=track, args=args, registry=registry)


class _Span:
    """Context manager recording its body as one span on exit."""

    __slots__ = ("name", "cat", "track", "args", "registry", "t0", "t1")

    def __init__(self, name, cat, track, args, registry):
        self.name = name
        self.cat = cat
        self.track = track
        self.args = args
        self.registry = registry
        self.t0 = 0.0
        self.t1 = 0.0

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.t1 = time.perf_counter()
        if exc_type is not None:
            self.args = dict(self.args)
            self.args["error"] = exc_type.__name__
        record_span(
            self.name,
            self.t0,
            self.t1,
            cat=self.cat,
            track=self.track,
            args=self.args,
            registry=self.registry,
        )


def span(
    name: str,
    cat: str = "",
    track: str = "",
    registry: Optional[Registry] = None,
    **args: Any,
):
    """``with obs.span("prefill", cat="serve"): ...`` — records the body's
    wall interval as a span. Returns a shared null context when tracing is
    disabled (no allocation, no registry access)."""
    if not _enabled:
        return _NULL
    return _Span(name, cat, track, args, registry)


def export_chrome_trace(path: Optional[str], registry: Optional[Registry] = None) -> Dict[str, Any]:
    """Export the registry's spans as Chrome trace-event JSON.

    Each distinct span ``track`` becomes one named thread row (``"M"``
    thread_name metadata); spans become complete ``"X"`` events with
    ``ts``/``dur`` in microseconds, rebased so the earliest span starts at
    0.  Writes to ``path`` when given; always returns the trace dict.
    Open the file at https://ui.perfetto.dev or ``chrome://tracing``.
    """
    reg = registry if registry is not None else get_registry()
    spans = sorted(reg.spans(), key=lambda s: s["ts"])
    base = spans[0]["ts"] if spans else 0.0
    events = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": 0,
            "tid": 0,
            "args": {"name": "repro"},
        }
    ]
    tids: Dict[str, int] = {}
    for s in spans:
        tids.setdefault(s["track"], len(tids))
    for track, tid in tids.items():
        events.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": 0,
                "tid": tid,
                "args": {"name": track},
            }
        )
    for s in spans:
        events.append(
            {
                "name": s["name"],
                "cat": s["cat"] or "repro",
                "ph": "X",
                "ts": round((s["ts"] - base) * 1e6, 3),
                "dur": round(s["dur"] * 1e6, 3),
                "pid": 0,
                "tid": tids[s["track"]],
                "args": s["args"],
            }
        )
    trace = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path:
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace
