"""Device-side kernel telemetry: launch counts measured at execution time.

The dispatch-time counters in ``kernels/ops.py``
(``kernels.<op>.kernel_calls|fallback_calls``) fire once per *traced call
site*: under ``jax.jit`` the wrapper's Python body runs at trace time, so
a decode burst that scans a kernel K times still counts 1.  This module
closes that gap.  Kernels accumulate a small int32 telemetry buffer
*in-kernel* (launch flag, sampled-block counts — see ``TEL_WIDTH`` lanes
in kernels/mca_matmul.py), the wrapper hands the traced values to
:func:`emit` / :func:`emit_vec`, and a ``jax.debug.callback`` delivers
them to a process-global accumulator once per device execution —
including every iteration of a ``lax.scan`` and every call of a compiled
function.

Metric names follow the registry convention:

* ``kernels.<op>.device_launches`` — executions of the op (kernel or
  fallback body), counted on the device path;
* ``kernels.<op>.device_sampled_blocks`` — MCA ops: sampled block
  contributions actually accumulated in-kernel (the ragged kernel's
  ``pl.when(k < r_tile[i])`` skipping makes this device-only truth);
* ``kernels.<op>.device_rows_written`` / ``device_tiles`` — per-op extras;
* ``mca.device_tier_hist.t{i}`` — per-tier token counts emitted by
  ``core.policy.mca_project`` at execution time (must agree with the
  stats-pytree ``tier_hist``).

:meth:`repro.obs.Registry.snapshot` merges accumulated totals into its
``counters`` section, windowed to activity since the registry was created
(so ``obs.scoped()`` collection keeps working).  The store itself is
process-global: device truth has no thread affinity (callbacks run on
runtime threads, not the caller's).

Disabled by default.  When off, :func:`emit` is a trace-time no-op — no
callback is staged, nothing runs on device or host.  The flag is read at
TRACE time: enable telemetry *before* the first compilation of the code
you want counted; already-compiled executables will not retrace when the
flag flips.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Dict, Iterator, Sequence

_lock = threading.Lock()
_totals: Dict[str, float] = {}
_enabled = False
_ever_enabled = False       # gates the (jax) effects barrier in sync()


def enable(flag: bool = True) -> None:
    """Turn device telemetry on/off (trace-time flag; see module doc)."""
    global _enabled, _ever_enabled
    _enabled = bool(flag)
    _ever_enabled = _ever_enabled or _enabled


def enabled() -> bool:
    return _enabled


@contextlib.contextmanager
def enabled_scope(flag: bool = True) -> Iterator[None]:
    """Temporarily flip the telemetry flag (tests)."""
    global _enabled
    prev = _enabled
    enable(flag)
    try:
        yield
    finally:
        _enabled = prev


def _accum(names: tuple, values) -> None:
    """Callback target: runs once per device execution."""
    import numpy as np
    vals = np.ravel(np.asarray(values))
    with _lock:
        for name, v in zip(names, vals):
            _totals[name] = _totals.get(name, 0.0) + float(v)


def emit(name: str, value) -> None:
    """Stage a per-execution accumulation of ``value`` into ``name``.

    ``value`` may be a traced scalar or a plain number; the callback fires
    every time the enclosing computation executes on device.  No-op (and
    zero cost) when telemetry is disabled at trace time.
    """
    emit_vec((name,), (value,))


def emit_vec(names: Sequence[str], values) -> None:
    """Stage accumulation of a small vector; ``values`` is a traced array
    or a sequence of scalars, matched to ``names`` by position."""
    if not _enabled:
        return
    import jax
    import jax.numpy as jnp
    if isinstance(values, (list, tuple)):
        values = jnp.stack([jnp.asarray(v, jnp.float32) for v in values])
    else:
        values = jnp.asarray(values, jnp.float32)
    jax.debug.callback(functools.partial(_accum, tuple(names)), values)


def sync() -> None:
    """Block until staged callbacks have delivered (device truth is
    asynchronous); no-op if telemetry was never enabled this process."""
    if not _ever_enabled:
        return
    import jax
    jax.effects_barrier()


def totals() -> Dict[str, float]:
    """Copy of the process-global accumulated totals (after a sync)."""
    sync()
    with _lock:
        return dict(_totals)


def since(base: Dict[str, float]) -> Dict[str, float]:
    """Accumulation deltas vs a baseline captured by :func:`totals`;
    zero-delta names are dropped."""
    cur = totals()
    out = {}
    for name, v in cur.items():
        d = v - base.get(name, 0.0)
        if d != 0.0:
            out[name] = d
    return out


def reset() -> None:
    """Clear the process-global store (tests)."""
    sync()
    with _lock:
        _totals.clear()
