"""Process-global metrics registry: counters, gauges, histograms, timers.

Design goals (in order): zero hot-path cost when unused, no dependencies,
safe under threads (the trainer's watchdog and the async checkpointer both
live on side threads), and trivially serializable snapshots for the JSONL
sink and the benchmark JSON.

Scoping: ``get_registry()`` returns the innermost registry opened with
``scoped()`` on this thread, else the process-global one.  ``scoped()`` is
how tests and benchmarks collect an isolated snapshot without resetting
global state:

    with obs.scoped() as reg:
        run_training_step()
        assert reg.counter("train.steps").value == 1

Values recorded may be Python numbers or 0-d jax/numpy arrays; they are
coerced to float at record time so snapshots never hold device buffers.
"""
from __future__ import annotations

import contextlib
import math
import threading
import time
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional

from . import devtel


def _as_float(v) -> float:
    try:
        return float(v)
    except TypeError:           # pragma: no cover - exotic array wrappers
        import numpy as np
        return float(np.asarray(v))


class Counter:
    """Monotonically increasing count (events, tokens, fallbacks)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value: float = 0.0

    def inc(self, n=1) -> None:
        n = _as_float(n)
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins scalar (flops reduction, slot occupancy)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value: Optional[float] = None

    def set(self, v) -> None:
        v = _as_float(v)
        with self._lock:
            self.value = v


class Histogram:
    """Streaming summary stats plus a bounded sample reservoir.

    Keeps exact count/sum/min/max and the most recent ``max_samples``
    observations for percentile estimates — enough for per-step latency
    distributions without unbounded memory.
    """

    def __init__(self, max_samples: int = 1024) -> None:
        self._lock = threading.Lock()
        self._max = max_samples
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._samples: List[float] = []

    def observe(self, v) -> None:
        v = _as_float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            if len(self._samples) >= self._max:
                # drop the oldest half; recency beats uniformity for perf
                self._samples = self._samples[self._max // 2:]
            self._samples.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def percentile(self, p: float) -> float:
        """Approximate percentile over the retained samples; p in [0, 100]."""
        with self._lock:
            if not self._samples:
                return math.nan
            xs = sorted(self._samples)
        i = min(len(xs) - 1, max(0, round(p / 100.0 * (len(xs) - 1))))
        return xs[i]

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.total, "mean": self.mean,
                "min": self.min if self.min is not None else math.nan,
                "max": self.max if self.max is not None else math.nan,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class _Timer:
    def __init__(self, hist: Histogram) -> None:
        self._hist = hist

    def __enter__(self) -> "_Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._hist.observe(time.perf_counter() - self._t0)


class Registry:
    """Name-keyed metric store; metrics auto-create on first access."""

    # Bound on retained spans per registry; beyond it the oldest are
    # dropped (and counted) so a long serve run cannot grow unbounded.
    MAX_SPANS = 50_000

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._spans: Deque[dict] = deque(maxlen=self.MAX_SPANS)
        self.spans_dropped = 0
        # Device-telemetry window: this registry reports only accumulation
        # since its creation (so obs.scoped() isolation extends to devtel).
        self._dev_base = devtel.totals()

    def counter(self, name: str) -> Counter:
        with self._lock:
            return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            return self._hists.setdefault(name, Histogram())

    def timer(self, name: str) -> _Timer:
        """Context manager recording elapsed seconds into histogram ``name``."""
        return _Timer(self.histogram(name))

    def add_span(self, span: dict) -> None:
        """Append a completed tracing span (see obs.tracing); bounded."""
        with self._lock:
            if len(self._spans) == self._spans.maxlen:
                self.spans_dropped += 1
            self._spans.append(span)

    def spans(self) -> List[dict]:
        """Copy of the retained spans, in record order."""
        with self._lock:
            return list(self._spans)

    def snapshot(self, include_device: bool = True) -> Dict[str, Dict]:
        """Plain-dict view of every metric (JSON-serializable).

        Device-telemetry totals accumulated since this registry was
        created (``kernels.<op>.device_launches`` etc., see obs.devtel)
        are merged into ``counters``; spans are not included — use
        :meth:`spans` / ``obs.export_chrome_trace``.
        """
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        counter_vals = {k: c.value for k, c in counters.items()}
        if include_device:
            counter_vals.update(devtel.since(self._dev_base))
        return {
            "counters": {k: counter_vals[k] for k in sorted(counter_vals)},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.summary() for k, h in sorted(hists.items())},
        }

    def reset(self) -> None:
        dev_base = devtel.totals()
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._spans.clear()
            self.spans_dropped = 0
            self._dev_base = dev_base


_GLOBAL = Registry()
_scopes = threading.local()


def _scope_stack() -> List[Registry]:
    if not hasattr(_scopes, "stack"):
        _scopes.stack = []
    return _scopes.stack


def get_registry() -> Registry:
    """Innermost scoped registry on this thread, else the global one."""
    stack = _scope_stack()
    return stack[-1] if stack else _GLOBAL


@contextlib.contextmanager
def scoped(registry: Optional[Registry] = None) -> Iterator[Registry]:
    """Route ``get_registry()`` to a fresh (or given) registry in this scope."""
    reg = registry if registry is not None else Registry()
    _scope_stack().append(reg)
    try:
        yield reg
    finally:
        _scope_stack().pop()
