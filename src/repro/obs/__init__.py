"""repro.obs — lightweight observability for the MCA pipeline.

Three pieces, importable as ``from repro import obs``:

- metrics: ``obs.get_registry()`` returns the active :class:`Registry`
  (counters / gauges / histograms / timers); ``obs.scoped()`` isolates
  collection for a test or a benchmark run.
- tracing: ``obs.trace("name")`` / ``@obs.annotate("name")`` emit
  ``jax.profiler`` spans on the hot paths (no-ops without a profiler).
- sink: ``obs.JsonlSink(path)`` appends structured JSON-lines records.

Metric naming convention: dotted ``<area>.<metric>`` —
``kernels.flash_attention.kernel_calls``, ``train.flops_reduction``,
``serve.wave_seconds``.  See ROADMAP.md § Observability for the full list.
"""
from .registry import (Counter, Gauge, Histogram, Registry, get_registry,
                       scoped)
from .sink import JsonlSink, read_jsonl
from .trace import annotate, trace

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "get_registry", "scoped",
    "JsonlSink", "read_jsonl", "annotate", "trace",
]
