"""repro.obs — lightweight observability for the MCA pipeline.

Five pieces, importable as ``from repro import obs``:

- metrics: ``obs.get_registry()`` returns the active :class:`Registry`
  (counters / gauges / histograms / timers); ``obs.scoped()`` isolates
  collection for a test or a benchmark run; ``obs.snapshot()`` snapshots
  the active registry and with ``aggregate="psum"`` sums additive leaves
  across SPMD processes.
- spans: ``obs.span(name, cat=..., track=...)`` records host-side
  timeline spans (request chains, trainer steps) when enabled via
  ``obs.enable_tracing()`` / ``obs.tracing()``;
  ``obs.export_chrome_trace(path)`` writes Perfetto-loadable JSON.
- device telemetry: ``obs.devtel`` accumulates per-execution kernel
  launch / sampled-block counts delivered from the device
  (``kernels.<op>.device_launches`` — vs the dispatch-time
  ``kernel_calls`` which count traced call sites).
- profiler hooks: ``obs.trace("name")`` / ``@obs.annotate("name")`` emit
  ``jax.profiler`` annotations on the hot paths (no-ops without a
  profiler).
- sink: ``obs.JsonlSink(path)`` appends structured JSON-lines records
  (flushed per write; fsync on close).

Metric naming convention: dotted ``<area>.<metric>`` —
``kernels.flash_attention.kernel_calls``, ``train.flops_reduction``,
``serve.wave_seconds``.  See ROADMAP.md § Observability for the full list.
"""
from . import devtel
from .aggregate import snapshot
from .registry import (Counter, Gauge, Histogram, Registry, get_registry,
                       scoped)
from .sink import JsonlSink, read_jsonl
from .trace import annotate, trace
from .tracing import (enable_tracing, export_chrome_trace, mark, record_span,
                      span, tracing, tracing_enabled)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "get_registry", "scoped",
    "snapshot", "JsonlSink", "read_jsonl", "annotate", "trace", "devtel",
    "enable_tracing", "tracing", "tracing_enabled", "span", "record_span",
    "mark", "export_chrome_trace",
]
