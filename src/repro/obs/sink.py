"""Structured JSON-lines sink for metric records.

One record per line, each a flat JSON object with a ``ts`` (unix seconds)
and a ``kind`` tag; everything else is caller-defined.  Append-only and
flushed per write so a crashed run still leaves a readable trail.

    sink = JsonlSink("metrics.jsonl")
    sink.write("train_step", step=3, loss=2.1, flops_reduction=8.7)
    sink.write_snapshot(obs.get_registry())
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

from .registry import Registry, get_registry


def _jsonable(v):
    """Coerce jax/numpy scalars and arrays so json.dumps never chokes."""
    if hasattr(v, "tolist"):
        return v.tolist()
    if hasattr(v, "item"):
        return v.item()
    return v


class JsonlSink:
    def __init__(self, path: str) -> None:
        self.path = path
        self._lock = threading.Lock()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)

    def write(self, kind: str, **fields) -> None:
        rec = {"ts": time.time(), "kind": kind}
        rec.update({k: _jsonable(v) for k, v in fields.items()})
        line = json.dumps(rec)
        with self._lock, open(self.path, "a") as f:
            f.write(line + "\n")

    def write_snapshot(self, registry: Optional[Registry] = None) -> None:
        reg = registry if registry is not None else get_registry()
        self.write("snapshot", **reg.snapshot())


def read_jsonl(path: str):
    """Load every record from a JSONL file (small files / tests only)."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
