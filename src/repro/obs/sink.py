"""Structured JSON-lines sink for metric records.

One record per line, each a flat JSON object with a ``ts`` (unix seconds)
and a ``kind`` tag; everything else is caller-defined.

Crash-safety contract: the file handle is opened once (append mode), every
``write`` emits exactly one line and flushes it to the OS, and ``close()``
``os.fsync``\\ s before closing — so a killed writer leaves only complete
JSON lines on disk (each line is handed to the kernel in a single
buffered-write flush).  Writes are serialized with a reentrant lock, so
concurrent batcher threads — and re-entrant writes from the same thread
(e.g. a snapshot triggered inside a write callback) — interleave at line
granularity, never mid-line.

    sink = JsonlSink("metrics.jsonl")
    sink.write("train_step", step=3, loss=2.1, flops_reduction=8.7)
    sink.write_snapshot(obs.get_registry())
    sink.close()          # or use it as a context manager
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

from .registry import Registry, get_registry


def _jsonable(v):
    """Coerce jax/numpy scalars and arrays so json.dumps never chokes."""
    if hasattr(v, "tolist"):
        return v.tolist()
    if hasattr(v, "item"):
        return v.item()
    return v


class JsonlSink:
    def __init__(self, path: str) -> None:
        self.path = path
        self._lock = threading.RLock()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a")

    def write(self, kind: str, **fields) -> None:
        rec = {"ts": time.time(), "kind": kind}
        rec.update({k: _jsonable(v) for k, v in fields.items()})
        line = json.dumps(rec) + "\n"
        with self._lock:
            if self._f is None:
                raise ValueError(f"JsonlSink({self.path!r}) is closed")
            self._f.write(line)
            self._f.flush()

    def write_snapshot(self, registry: Optional[Registry] = None) -> None:
        reg = registry if registry is not None else get_registry()
        self.write("snapshot", **reg.snapshot())

    def close(self) -> None:
        with self._lock:
            if self._f is None:
                return
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
            self._f = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort; explicit close() is the contract
        try:
            self.close()
        except Exception:
            pass


def read_jsonl(path: str):
    """Load every record from a JSONL file (small files / tests only)."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
