"""Profiler trace annotations for the MCA hot paths.

Thin wrappers over ``jax.profiler`` so call sites never need to guard on
profiler availability: if ``TraceAnnotation``/``annotate_function`` are
missing (old jax, stripped builds), these degrade to no-ops.

Annotations name trace-time work.  Under ``jax.jit`` the Python body runs
once per compilation, so a span around jitted code brackets *dispatch*,
not per-call device time — put spans around the blocking call sites
(e.g. ``block_until_ready`` loops, prefill/decode steps) when you want
wall-clock, and rely on ``annotate_function`` to label compiled regions
in the profiler timeline.
"""
from __future__ import annotations

import contextlib
from typing import Callable

try:                                       # pragma: no cover - import guard
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except ImportError:                        # pragma: no cover
    _TraceAnnotation = None

try:                                       # pragma: no cover - import guard
    from jax.profiler import annotate_function as _annotate_function
except ImportError:                        # pragma: no cover
    _annotate_function = None


def trace(name: str):
    """Context manager emitting a named profiler span (no-op without jax)."""
    if _TraceAnnotation is None:
        return contextlib.nullcontext()
    return _TraceAnnotation(name)


def annotate(name: str) -> Callable:
    """Decorator labelling a function's compiled region in profiler output."""
    def deco(fn: Callable) -> Callable:
        if _annotate_function is None:
            return fn
        return _annotate_function(fn, name=name)
    return deco
