"""SPMD-aggregated snapshots: psum counter/histogram leaves at snapshot time.

``obs.snapshot()`` is the module-level snapshot entry point.  With
``aggregate="psum"`` the additive leaves — every counter (including the
merged device-telemetry totals) plus each histogram's ``count``/``sum`` —
are summed across *all* processes with a ``lax.psum`` collective, and
histogram ``min``/``max`` are combined with ``pmin``/``pmax``, so every
process sees identical cluster-wide totals.  Per the repo's multi-device
test policy this is exercised by an 8-device subprocess test in
``tests/test_distributed.py``.

With world size 1 (single process, single device — e.g. the main pytest
process, which conftest pins to one CPU device) the call returns the
plain local snapshot without staging any collective.

Non-additive leaves stay local: gauges are last-write-wins per process,
and histogram ``mean`` is recomputed from the global sum/count while
``p50/p95/p99`` remain per-process sample estimates (noted in the README).

All processes must call ``snapshot(aggregate="psum")`` with the same
metric names in the same program order — standard collective discipline;
metric names are config-derived, not data-derived, so this holds.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

from .registry import Registry, get_registry


def snapshot(
    aggregate: Optional[str] = None,
    registry: Optional[Registry] = None,
    include_device: bool = True,
) -> Dict[str, Dict]:
    """Snapshot the active registry, optionally SPMD-aggregated.

    ``aggregate=None`` → local :meth:`Registry.snapshot`;
    ``aggregate="psum"`` → additive leaves summed across all processes
    (see module docstring). Anything else raises ``ValueError``.
    """
    reg = registry if registry is not None else get_registry()
    snap = reg.snapshot(include_device=include_device)
    if aggregate is None:
        return snap
    if aggregate != "psum":
        raise ValueError(f"unknown aggregate mode: {aggregate!r} (use None or 'psum')")
    return _psum_snapshot(snap)


def _psum_snapshot(snap: Dict[str, Dict]) -> Dict[str, Dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    if jax.process_count() == 1 and jax.device_count() == 1:
        return snap                      # world size 1: nothing to aggregate

    cnames = sorted(snap["counters"])
    hnames = sorted(snap["histograms"])
    sums = [float(snap["counters"][k]) for k in cnames]
    mins, maxs = [], []
    for k in hnames:
        h = snap["histograms"][k]
        sums += [float(h["count"]), float(h["sum"])]
        # nan (empty histogram) must not poison pmin/pmax on other hosts
        mins.append(float(h["min"]) if not math.isnan(h["min"]) else math.inf)
        maxs.append(float(h["max"]) if not math.isnan(h["max"]) else -math.inf)
    if not sums and not mins:
        return snap

    n_local = jax.local_device_count()

    def _all(reduce_fn, vec, divide: bool):
        if not vec:
            return np.zeros((0,), np.float32)
        v = jnp.asarray(vec, jnp.float32)
        if divide:
            v = v / n_local              # each local replica carries 1/n_local
        tiled = jnp.tile(v[None], (n_local, 1))
        out = jax.pmap(lambda x: reduce_fn(x, "i"), axis_name="i")(tiled)
        return np.asarray(out[0])

    g_sum = _all(jax.lax.psum, sums, divide=True)
    g_min = _all(jax.lax.pmin, mins, divide=False)
    g_max = _all(jax.lax.pmax, maxs, divide=False)

    out = {
        "counters": {},
        "gauges": dict(snap["gauges"]),
        "histograms": {},
    }
    i = 0
    for k in cnames:
        out["counters"][k] = float(g_sum[i])
        i += 1
    for j, k in enumerate(hnames):
        h = dict(snap["histograms"][k])
        count, total = float(g_sum[i]), float(g_sum[i + 1])
        i += 2
        h["count"] = count
        h["sum"] = total
        h["mean"] = total / count if count else math.nan
        mn, mx = float(g_min[j]), float(g_max[j])
        h["min"] = mn if math.isfinite(mn) else math.nan
        h["max"] = mx if math.isfinite(mx) else math.nan
        out["histograms"][k] = h
    return out
