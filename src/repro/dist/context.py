"""Mesh context + sharding-constraint helpers.

Model code never mentions concrete axis names for the data-parallel
dimension: it writes ``constrain(x, DP, None, "model")`` and the helpers
resolve ``DP`` against whatever mesh is active — ``("data",)`` on a single
pod, ``("pod", "data")`` on the multi-pod mesh.  With no active mesh every
helper is an exact no-op, so the same model code runs unmodified on a
single CPU device in tests.

All constraints are *advisory divisible shardings*: if a dimension does
not divide evenly over the requested axes the entry is dropped (replicated)
rather than letting GSPMD pad — padding an MCA sample dimension would
silently skew the estimator's FLOPs accounting.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class _AxisSpec:
    """Sentinel resolved to concrete mesh axis names at constrain time."""

    def __init__(self, name: str, include_model: bool):
        self.name = name
        self.include_model = include_model

    def __repr__(self) -> str:                               # pragma: no cover
        return self.name


#: the data-parallel axes — ("data",) or ("pod", "data")
DP = _AxisSpec("DP", include_model=False)
#: every mesh axis (batch-over-everything fallback for indivisible seq)
DPM = _AxisSpec("DPM", include_model=True)

_local = threading.local()


def _stack() -> list:
    if not hasattr(_local, "mesh_stack"):
        _local.mesh_stack = []
    return _local.mesh_stack


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate ``mesh`` for the dynamic extent (usable re-entrantly)."""
    _stack().append(mesh)
    try:
        yield mesh
    finally:
        _stack().pop()


def get_mesh() -> Optional[Mesh]:
    """The innermost active mesh, or None outside any ``use_mesh``."""
    stack = _stack()
    return stack[-1] if stack else None


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All non-tensor-parallel axis names, outermost first."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _resolve_entry(mesh: Mesh, entry):
    """spec entry -> tuple of axis names (possibly empty)."""
    if entry is None:
        return ()
    if isinstance(entry, _AxisSpec):
        axes = dp_axes(mesh)
        if entry.include_model and "model" in mesh.axis_names:
            axes = axes + ("model",)
        return axes
    if isinstance(entry, str):
        return (entry,) if entry in mesh.axis_names else ()
    return tuple(a for a in entry if a in mesh.axis_names)


def _spec_entry(axes: Tuple[str, ...]):
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint under the active mesh (no-op without one).

    ``spec`` entries are per-dimension: None (replicated), an axis name,
    a tuple of names, or the DP / DPM sentinels.  Entries whose combined
    axis size does not divide the dimension are dropped.
    """
    mesh = get_mesh()
    if mesh is None or mesh.size == 1:
        return x
    entries = []
    for dim, entry in enumerate(spec):
        axes = _resolve_entry(mesh, entry)
        if axes and (dim >= x.ndim or x.shape[dim] % _axis_size(mesh, axes)):
            axes = ()
        entries.append(_spec_entry(axes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def constrain_heads(x: jax.Array, *, head_dims: Sequence[int],
                    batch_dim: int = 0) -> jax.Array:
    """Megatron-TP activation constraint: batch over DP, one head dim over
    "model".

    ``head_dims`` are candidate dimensions in preference order; the first
    whose size divides the model axis gets it (GQA repeats KV heads first
    when only the full q-head count divides — see models/attention.py).
    """
    mesh = get_mesh()
    if mesh is None or mesh.size == 1:
        return x
    nm = mesh.shape.get("model", 1)
    spec = [None] * x.ndim
    spec[batch_dim] = DP
    if nm > 1:
        for dim in head_dims:
            if x.shape[dim] % nm == 0:
                spec[dim] = "model"
                break
    return constrain(x, *spec)


def constrain_residual(x: jax.Array, attn_parallel: str = "auto"
                       ) -> jax.Array:
    """Residual-stream constraint at layer boundaries: [B, S, d] with batch
    over DP and — Megatron sequence-parallel — seq over "model" so saved
    activations shrink n_model-fold.  ``attn_parallel == "dp"`` keeps the
    sequence replicated (pure data parallelism).
    """
    mesh = get_mesh()
    if mesh is None or mesh.size == 1:
        return x
    nm = mesh.shape.get("model", 1)
    seq_ok = (attn_parallel != "dp" and nm > 1 and x.ndim >= 2
              and x.shape[1] % nm == 0)
    return constrain(x, DP, "model" if seq_ok else None, None)
