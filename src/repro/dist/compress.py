"""Error-feedback gradient compression for cross-pod all-reduce.

Cross-pod (DCN) bandwidth is ~20x below ICI; int8-quantizing the gradient
cuts the transfer 4x.  Plain quantization biases training; error feedback
(Seide et al. 2014 / Karimireddy et al. 2019) carries the quantization
residual into the next step, so the *sum over time* of transmitted
gradients telescopes to the true sum — compression becomes unbiased over
the trajectory (tests/test_substrate.py::TestGradCompression checks the
telescoping identity exactly).

All helpers are shard_map-compatible pure functions over pytrees.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_QMAX = 127.0  # symmetric int8


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization.

    Returns (q int8, scale f32 scalar) with g ~= q * scale.
    """
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.where(amax > 0, amax / _QMAX, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g32 / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_buffer(tree):
    """Zero residuals matching ``tree`` (always f32 — the residual is a
    numerical correction term, never cast down)."""
    return jax.tree.map(
        lambda x: jnp.zeros(jnp.shape(x), jnp.float32), tree)


def ef_compress_tree(grads, err):
    """Error-feedback compression of a gradient pytree.

    Compensates each leaf with its carried residual, quantizes, and
    returns (q_tree, scale_tree, new_err) where
    ``new_err = (g + err) - dequantize(q, s)`` — by construction
    ``sum_t dequant_t + err_T == sum_t g_t`` exactly (telescoping).
    """
    comp = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, err)
    flat, treedef = jax.tree.flatten(comp)
    qs = [quantize(c) for c in flat]
    q_tree = jax.tree.unflatten(treedef, [q for q, _ in qs])
    s_tree = jax.tree.unflatten(treedef, [s for _, s in qs])
    new_err = jax.tree.unflatten(
        treedef, [c - dequantize(q, s) for c, (q, s) in zip(flat, qs)])
    return q_tree, s_tree, new_err


def psum_compressed(grads, err, axis_name: str):
    """Compressed gradient all-reduce inside shard_map.

    Each shard EF-compresses its local gradient and the *dequantized*
    int8 payloads are psum'd over ``axis_name`` (on the wire this is the
    int8 tensor + one f32 scale; the f32 psum here is the semantic
    equivalent XLA sees).  Returns (summed_grads, new_err); residuals
    stay shard-local, which is exactly what makes distributed EF correct.
    """
    q_tree, s_tree, new_err = ef_compress_tree(grads, err)
    summed = jax.tree.map(
        lambda q, s: jax.lax.psum(dequantize(q, s), axis_name),
        q_tree, s_tree)
    return summed, new_err


def compression_ratio(grads) -> float:
    """Wire-bytes ratio of f32 grads vs int8+scale payload (static)."""
    f32 = sum(leaf.size * 4 for leaf in jax.tree.leaves(grads))
    int8 = sum(leaf.size + 4 for leaf in jax.tree.leaves(grads))
    return f32 / max(int8, 1)
