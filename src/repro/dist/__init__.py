"""SPMD distribution substrate.

``context``   mesh context manager + sharding-constraint helpers used
              inside model code (attention / ffn / stacks / policy).
``sharding``  NamedSharding trees for params / optimizer / batches /
              KV-caches consumed by train/step.py and launch/dryrun.py.
``compress``  error-feedback int8 gradient compression for cross-pod
              all-reduce (DCN is ~20x slower than ICI).
"""
from . import compress, context, sharding
from .context import (DP, DPM, constrain, constrain_heads,
                      constrain_residual, dp_axes, get_mesh, use_mesh)

__all__ = [
    "DP", "DPM", "compress", "constrain", "constrain_heads",
    "constrain_residual", "context", "dp_axes", "get_mesh", "sharding",
    "use_mesh",
]
