"""NamedSharding trees for params, optimizer state, batches and caches.

Placement rules are name-keyed (the param trees are plain dicts) and use
*negative* dimension indices so the same rule covers a bare leaf and its
layer-stacked form ([d, f] and [L, d, f] alike).  Every rule is guarded by
divisibility — a dimension that does not divide the axis stays replicated,
so arbitrary reduced test configs always produce valid shardings.

Weight layout follows Megatron TP:
  column-parallel (output dim over "model"):  wq wk wv w_up w_gate ...
  row-parallel    (input dim over "model"):   wo w_down out_proj w_out
  embedding table: vocab over "model" (padded_vocab is 128-aligned)
ZeRO-1 additionally shards every optimizer moment (and, under FSDP, the
params themselves) over the data axes on the first replicated dimension
that divides.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .context import dp_axes

# output (last) dim over "model"
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "w_up", "w_gate", "w_uq", "w_uk", "w_uv",
    "in_proj", "w_gelu", "w_rec", "w_a", "w_i", "lm_head", "patch_proj",
})
# input (second-to-last) dim over "model"
_ROW_PARALLEL = frozenset({"wo", "w_down", "out_proj", "w_out", "table"})

# cache leaf name -> (batch dim, model-sharded dim or None), negative
# indices so stacked ([L, B, ...]) and unstacked ([B, ...]) leaves match.
_CACHE_DIMS = {
    "k": (-4, -2), "v": (-4, -2),
    "cross_k": (-4, -2), "cross_v": (-4, -2),
    "ckv": (-3, None), "kr": (-3, None),
    "state": (-5, None), "conv": (-3, None), "h": (-2, None),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _dp_entry(mesh: Mesh):
    dp = dp_axes(mesh)
    if not dp:
        return None
    return dp[0] if len(dp) == 1 else dp


def _n_dp(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ----------------------------------------------------------------- params
def param_shardings(mesh: Mesh, a_params, cfg=None):
    """Tensor-parallel NamedSharding tree matching ``a_params``.

    ``cfg`` is accepted for call-site symmetry (rules are shape/name
    driven, so one implementation covers every model family).
    """
    nm = mesh.shape.get("model", 1)

    def rule(path, leaf):
        name = _leaf_name(path)
        if leaf.ndim < 2 or nm <= 1:
            return _replicated(mesh)
        spec = [None] * leaf.ndim
        if name in _COL_PARALLEL and leaf.shape[-1] % nm == 0:
            spec[-1] = "model"
        elif name in _ROW_PARALLEL and leaf.shape[-2] % nm == 0:
            spec[-2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, a_params)


def zero1_shardings(mesh: Mesh, p_sh, a_params):
    """ZeRO-1: additionally shard each leaf over the data axes on the
    first replicated dimension that divides (layer-stacked leaves shard
    the layer dim, giving per-layer moment shards like optimizer-state
    partitioning in DeepSpeed stage 1)."""
    n_dp = _n_dp(mesh)
    dp = _dp_entry(mesh)

    def rule(sh, leaf):
        if n_dp <= 1 or leaf.ndim == 0:
            return sh
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        for dim in range(leaf.ndim):
            if spec[dim] is None and leaf.shape[dim] % n_dp == 0:
                spec[dim] = dp
                return NamedSharding(mesh, P(*spec))
        return sh

    return jax.tree_util.tree_map(rule, p_sh, a_params)


# ------------------------------------------------------------------ data
def batch_shardings(mesh: Mesh, abstract_batch):
    """Batch leaves shard dim 0 over the data axes (replicate if it does
    not divide — e.g. tiny smoke batches on big meshes)."""
    n_dp = _n_dp(mesh)
    dp = _dp_entry(mesh)

    def rule(leaf):
        if leaf.ndim == 0 or n_dp <= 1 or leaf.shape[0] % n_dp != 0:
            return _replicated(mesh)
        return NamedSharding(mesh, P(*([dp] + [None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(rule, abstract_batch)


def cache_shardings(mesh: Mesh, abstract_cache):
    """KV / recurrent-state cache shardings: batch over data axes, KV
    heads over "model" where they divide.  Unknown leaves (slot_pos,
    scalars) stay replicated — decode donates the cache, so in/out specs
    must be reproducible from structure alone."""
    n_dp = _n_dp(mesh)
    nm = mesh.shape.get("model", 1)
    dp = _dp_entry(mesh)

    def rule(path, leaf):
        dims = _CACHE_DIMS.get(_leaf_name(path))
        if dims is None:
            return _replicated(mesh)
        batch_dim, model_dim = dims
        if leaf.ndim < -batch_dim:
            return _replicated(mesh)
        spec = [None] * leaf.ndim
        if n_dp > 1 and leaf.shape[batch_dim] % n_dp == 0:
            spec[batch_dim] = dp
        if (model_dim is not None and nm > 1
                and leaf.ndim >= -model_dim
                and leaf.shape[model_dim] % nm == 0):
            spec[model_dim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


def describe(shardings) -> Tuple[str, ...]:
    """Human-readable one-liner per leaf (debug helper for dryrun logs)."""
    lines = []
    for path, sh in jax.tree_util.tree_flatten_with_path(shardings)[0]:
        lines.append(f"{jax.tree_util.keystr(path)}: {sh.spec}")
    return tuple(lines)
