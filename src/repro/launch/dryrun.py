import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell
on 512 placeholder devices, record memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k [--multi-pod] [--mca] [--out dryrun_results]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results are JSON-cached per cell; re-runs skip completed cells.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config
from repro.core.policy import MCAConfig
from repro.dist import context as dctx
from repro.dist import sharding as shd
from repro.launch import hlo_analysis
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.specs import input_specs
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.step import (abstract_state, make_prefill_step,
                              make_train_step, train_step_shardings)


def _mca_cfg(enabled: bool) -> MCAConfig:
    return MCAConfig(enabled=enabled, alpha=0.2, block=128,
                     sites=("v_proj",))


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               mca: bool = False, extra_overrides=None):
    """Returns (lowered, compiled, meta) for one cell."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = dict(extra_overrides or {})
    overrides.setdefault("mca", _mca_cfg(mca))
    # "mca_<field>" overrides patch the MCAConfig (perf_iter --set)
    import dataclasses as _dc
    mca_patch = {k[4:]: overrides.pop(k)
                 for k in list(overrides) if k.startswith("mca_")}
    if mca_patch:
        overrides["mca"] = _dc.replace(overrides["mca"], **mca_patch)
    n_micro = overrides.pop("n_micro", 1)
    seq_override = overrides.pop("_seq_override", None)
    cfg, kind, specs = input_specs(arch, shape, **overrides)
    seq, batch, _ = SHAPES[shape]
    if seq_override is not None:
        from repro.launch import specs as specs_mod
        seq = seq_override
        if kind == "train":
            specs = specs_mod.train_specs(cfg, seq, batch)
        elif kind == "prefill":
            specs = specs_mod.prefill_specs(cfg, seq, batch)
        else:
            specs = specs_mod.decode_specs(cfg, seq, batch)
    model = build_model(cfg)

    with dctx.use_mesh(mesh):
        a_params, a_opt = abstract_state(model)
        p_sh = shd.param_shardings(mesh, a_params, cfg)
        if kind == "train":
            step = make_train_step(model, AdamWConfig(), n_micro=n_micro,
                                   seed=0, with_mca=mca)
            in_sh, out_sh = train_step_shardings(mesh, model, specs)
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh,
                              donate_argnums=(0, 1)
                              ).lower(a_params, a_opt, specs)
        elif kind == "prefill":
            prefill = make_prefill_step(model, max_len=seq, with_mca=mca)
            b_sh = shd.batch_shardings(mesh, specs)
            a_out = jax.eval_shape(prefill, a_params, specs)
            c_sh = shd.cache_shardings(mesh, a_out[0])
            lowered = jax.jit(prefill, in_shardings=(p_sh, b_sh),
                              out_shardings=(c_sh, None)
                              ).lower(a_params, specs)
        else:  # decode
            a_tok, a_cache, a_t = specs

            def decode(params, tok, cache, t):
                return model.decode(params, tok, cache, t)

            c_sh = shd.cache_shardings(mesh, a_cache)
            t_sh = shd.batch_shardings(mesh, a_tok)
            lowered = jax.jit(
                decode,
                in_shardings=(p_sh, t_sh, c_sh, None),
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            ).lower(a_params, a_tok, a_cache, a_t)
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
    return lowered, compiled, {"kind": kind, "seq": seq, "batch": batch,
                               "compile_s": compile_s, "cfg": cfg}


def analyze(compiled, meta, mesh_devices: int) -> dict:
    out = {"devices": mesh_devices, **{k: meta[k] for k in
                                       ("kind", "seq", "batch",
                                        "compile_s")}}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        out["flops"] = float(cost.get("flops", 0.0))
        out["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:                                   # noqa: BLE001
        out["cost_error"] = repr(e)
    try:
        mem = compiled.memory_analysis()
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            if hasattr(mem, attr):
                out[attr] = int(getattr(mem, attr))
    except Exception as e:                                   # noqa: BLE001
        out["memory_error"] = repr(e)
    text = compiled.as_text()
    out["collectives"] = hlo_analysis.collective_stats(text)
    out["op_census"] = hlo_analysis.op_census(text)
    out["hlo_chars"] = len(text)
    return out


def roofline_terms(result: dict) -> dict:
    """Three roofline terms (seconds) from a single-device analysis."""
    flops = result.get("flops", 0.0)
    bytes_acc = result.get("bytes_accessed", 0.0)
    coll = result.get("collectives", {}).get("total_bytes", 0)
    terms = {
        "t_compute": flops / HW["peak_bf16_flops"],
        "t_memory": bytes_acc / HW["hbm_bw"],
        "t_collective": coll / HW["ici_bw"],
    }
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                              if k.startswith("t_") else -1)
    return terms


# ---------------------------------------------------------------- analysis
def _depth_overrides(cfg, units: int) -> dict:
    """Config overrides setting the repeated-stack depth to ``units``."""
    if cfg.family == "hybrid":
        pat = len(cfg.block_pattern)
        rem = cfg.n_layers % pat
        return {"n_layers": pat * units + rem}
    if cfg.is_encoder_decoder:
        return {"n_layers": units, "n_encoder_layers": units}
    return {"n_layers": units}


def _real_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // len(cfg.block_pattern)
    return cfg.n_layers


def n_params(cfg) -> dict:
    """Total / active / non-embedding parameter counts from eval_shape."""
    import math
    from repro.models import build_model
    model = build_model(cfg)
    a = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(a)[0]
    total = active = embed = 0
    for path, leaf in flat:
        n = math.prod(leaf.shape)
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        total += n
        if name == "table":
            embed += n
            continue
        if cfg.n_experts and name in ("w_up", "w_gate", "w_down") \
                and leaf.ndim >= 3:
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return {"total": total, "active_nonembed": active, "embed": embed}


def model_flops(cfg, kind: str, seq: int, batch: int) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd-only);
    N excludes the embedding gather, includes the logits head."""
    counts = n_params(cfg)
    tokens = batch * (seq if kind != "decode" else 1)
    mult = 6 if kind == "train" else 2
    return mult * counts["active_nonembed"] * tokens


def analyze_cell_extrapolated(arch: str, shape: str, *, mca: bool) -> dict:
    """HLO cost via depth extrapolation: lower fully-unrolled 1- and 2-unit
    stacks (inner scans unrolled too, so cost_analysis sees every op), fit
    cost(L) = a + b*L, evaluate at the real depth.

    For attention-free (SSM) prefill cells every cost term is linear in
    sequence length, so the unrolled analysis runs at seq=4096 and scales
    by S/4096 — unrolling 512 SSD chunk steps at 32k seq is compile-
    prohibitive and adds no information."""
    seq, batch, kind = SHAPES[shape]
    base_cfg = get_config(arch)
    units_real = _real_units(base_cfg)
    seq_scale = 1.0
    shape_ov = {}
    if (kind == "prefill" and base_cfg.family == "ssm" and seq > 4096):
        seq_scale = seq / 4096.0
        shape_ov["_seq_override"] = 4096
    results = {}
    for units in (1, 2):
        ov = _depth_overrides(base_cfg, units)
        ov.update(unroll_layers=True, unroll_inner=True)
        ov.update(shape_ov)
        lowered, compiled, meta = lower_cell(
            arch, shape, multi_pod=False, mca=mca, extra_overrides=ov)
        results[units] = analyze(compiled, meta, 256)

    def fit(key, sub=None):
        v1 = results[1][key] if sub is None else results[1][key][sub]
        v2 = results[2][key] if sub is None else results[2][key][sub]
        if isinstance(v1, dict):
            v1, v2 = v1["bytes"], v2["bytes"]
        return v1 + (v2 - v1) * (units_real - 1)

    out = {
        "method": "unrolled depth extrapolation (units 1,2 -> "
                  f"{units_real})"
                  + (f" x seq-scale {seq_scale:.0f}" if seq_scale > 1
                     else ""),
        "flops": max(fit("flops"), 0.0) * seq_scale,
        "bytes_accessed": max(fit("bytes_accessed"), 0.0) * seq_scale,
        # units-1 constants can exceed the fit target (XLA folds more at
        # tiny depths); clamp at the per-unit slope floor
        "collective_bytes": max(fit("collectives", "total_bytes"), 0.0)
        * seq_scale,
        "per_unit": {
            "flops": results[2]["flops"] - results[1]["flops"],
            "bytes": (results[2]["bytes_accessed"]
                      - results[1]["bytes_accessed"]),
            "coll": (results[2]["collectives"]["total_bytes"]
                     - results[1]["collectives"]["total_bytes"]),
        },
        "units_real": units_real,
    }
    out["roofline"] = roofline_terms({
        "flops": out["flops"], "bytes_accessed": out["bytes_accessed"],
        "collectives": {"total_bytes": out["collective_bytes"]}})
    mf = model_flops(get_config(arch), kind, seq, batch)
    out["model_flops_global"] = mf
    out["model_flops_per_dev"] = mf / 256
    out["useful_fraction"] = (out["model_flops_per_dev"]
                              / max(out["flops"], 1.0))
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool, mca: bool,
             out_dir: str, force: bool = False) -> dict:
    tag = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}" \
          f"__{'mca' if mca else 'base'}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if "error" not in cached:
            print(f"[skip] {tag} (cached)")
            return cached
    print(f"[lower+compile] {tag} ...", flush=True)
    t0 = time.time()
    try:
        lowered, compiled, meta = lower_cell(arch, shape,
                                             multi_pod=multi_pod, mca=mca)
        n_dev = 512 if multi_pod else 256
        result = analyze(compiled, meta, n_dev)
        result["roofline_raw"] = roofline_terms(result)
        result["cell"] = {"arch": arch, "shape": shape,
                          "multi_pod": multi_pod, "mca": mca}
        if not multi_pod:
            # corrected HLO cost via depth extrapolation (scan bodies are
            # cost-counted once; see analyze_cell_extrapolated)
            try:
                result["corrected"] = analyze_cell_extrapolated(
                    arch, shape, mca=mca)
            except Exception:                                # noqa: BLE001
                result["corrected_error"] = traceback.format_exc()
        print(f"  ok in {time.time() - t0:.1f}s  "
              f"flops={result.get('flops', 0):.3e}  "
              f"coll={result['collectives']['total_bytes']:.3e}B")
    except Exception:                                        # noqa: BLE001
        result = {"cell": {"arch": arch, "shape": shape,
                           "multi_pod": multi_pod, "mca": mca},
                  "error": traceback.format_exc()}
        print(f"  FAILED in {time.time() - t0:.1f}s")
        print(result["error"].splitlines()[-1])
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mca", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="dryrun_results")
    args = ap.parse_args()

    todo = cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            res = run_cell(arch, shape, multi_pod=mp, mca=args.mca,
                           out_dir=args.out, force=args.force)
            failures += 1 if "error" in res else 0
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
