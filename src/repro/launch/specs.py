"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device allocation: these are the shapes/dtypes the launcher feeds to
jit(...).lower().  Shapes come from the assignment's per-arch shape sets
(repro.configs.SHAPES)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models import build_model

SDS = jax.ShapeDtypeStruct


def train_specs(cfg, seq: int, batch: int) -> Dict[str, SDS]:
    specs = {
        "tokens": SDS((batch, seq), jnp.int32),
        "labels": SDS((batch, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["patches"] = SDS((batch, cfg.n_patch_tokens, cfg.d_model),
                               jnp.bfloat16)
    if cfg.is_encoder_decoder:
        specs["frames"] = SDS((batch, cfg.encoder_len, cfg.d_model),
                              jnp.bfloat16)
    return specs


def prefill_specs(cfg, seq: int, batch: int) -> Dict[str, SDS]:
    specs = {"tokens": SDS((batch, seq), jnp.int32)}
    if cfg.family == "vlm":
        specs["patches"] = SDS((batch, cfg.n_patch_tokens, cfg.d_model),
                               jnp.bfloat16)
    if cfg.is_encoder_decoder:
        specs["frames"] = SDS((batch, cfg.encoder_len, cfg.d_model),
                              jnp.bfloat16)
    return specs


def decode_specs(cfg, seq: int, batch: int):
    """(tokens, cache, t) stand-ins; cache sized for a ``seq`` history."""
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(batch, seq))
    return (SDS((batch, 1), jnp.int32), cache,
            SDS((), jnp.int32))


def input_specs(arch: str, shape: str, **cfg_overrides
                ) -> Tuple[object, str, dict]:
    """Returns (cfg, kind, specs) for one dry-run cell."""
    seq, batch, kind = SHAPES[shape]
    cfg = get_config(arch, **cfg_overrides)
    if kind == "train":
        return cfg, kind, train_specs(cfg, seq, batch)
    if kind == "prefill":
        return cfg, kind, prefill_specs(cfg, seq, batch)
    return cfg, kind, decode_specs(cfg, seq, batch)
