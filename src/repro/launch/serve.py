"""Serving launcher: batched generation with the continuous batcher.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --reduced --requests 8 --max-new 16 [--mca --alpha 0.2] \
        [--per-slot [--check-every 8]]

``--per-slot`` serves with the ``SlotBatcher`` (per-request prefill
insertion + sync-free decode bursts) instead of the legacy wave batcher.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.policy import MCAConfig
from repro.models import build_model, reduced
from repro.serve import ContinuousBatcher, Engine, Request, SlotBatcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--mca", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.2)
    ap.add_argument("--per-slot", action="store_true",
                    help="use the per-slot SlotBatcher")
    ap.add_argument("--check-every", type=int, default=8,
                    help="decode burst length for --per-slot")
    args = ap.parse_args()

    mca = MCAConfig(enabled=args.mca, alpha=args.alpha, block=16,
                    sites=("v_proj",))
    cfg = get_config(args.arch, mca=mca)
    if args.reduced:
        cfg = reduced(cfg, mca=mca)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, batch_size=args.batch,
                    max_len=args.max_len, mca_enabled=args.mca)
    if args.per_slot:
        batcher = SlotBatcher(engine, check_every=args.check_every)
    else:
        batcher = ContinuousBatcher(engine)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        batcher.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
            max_new=args.max_new))
    done = batcher.run()
    dt = time.time() - t0
    tokens = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests / {tokens} tokens "
          f"in {dt:.2f}s ({tokens / dt:.1f} tok/s)")
    for uid in sorted(done)[:3]:
        print(f"  req {uid}: {done[uid][:8]}...")


if __name__ == "__main__":
    main()
