"""Post-SPMD HLO analysis: collective bytes + op census.

``compiled.as_text()`` is the per-device partitioned module, so the shapes
on collective ops are per-device; summing their result-buffer sizes gives
per-chip collective bytes for the roofline's collective term.
cost_analysis() does NOT expose these — this parser is the source of truth.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count + summed result bytes (per device)."""
    stats = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, rhs = line.split("=", 1)
        rhs = rhs.strip()
        # result type precedes the op name in "= TYPE opname("
        for kind in COLLECTIVES:
            # match the op name at the start of the instruction (after type)
            m = re.search(rf"\b{kind}(?:-start|-done)?\(", rhs)
            if m:
                type_str = rhs[:m.start()]
                # ignore -done (bytes counted at -start)
                if f"{kind}-done(" in rhs:
                    break
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += _shape_bytes(type_str)
                break
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def op_census(hlo_text: str, ops=("fusion", "dot", "custom-call",
                                  "while", "dynamic-slice",
                                  "dynamic-update-slice", "sort")) -> Dict:
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"= \S+ {op}\(", hlo_text))
    return out
