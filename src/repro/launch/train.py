"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b \
        --steps 200 --batch 32 --seq 1024 [--mca --alpha 0.2] \
        [--mesh data,model] [--n-micro 4] [--ckpt-dir ckpts/run1]

On a real TPU fleet this binary runs per-host under `jax.distributed`
initialization; on CPU it trains reduced configs for smoke/examples.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_config
from repro.core.policy import MCAConfig
from repro.data import SyntheticLM
from repro.dist import context as dctx
from repro.models import build_model, reduced
from repro.optim import adamw
from repro.train import Trainer, TrainerConfig
from repro.train.step import jit_train_step, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--mca", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.2)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke-size) config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-file", default=None,
                    help="optional memmap token file (data/write_token_file)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    mca = MCAConfig(enabled=args.mca, alpha=args.alpha, sites=("v_proj",))
    cfg = get_config(args.arch, mca=mca)
    if args.reduced:
        cfg = reduced(cfg, mca=mca if not args.mca else
                      MCAConfig(enabled=True, alpha=args.alpha, block=16,
                                sites=("v_proj",)))
    model = build_model(cfg)

    if args.data_file:
        from repro.data import MemmapLM
        data = MemmapLM(args.data_file, cfg.vocab_size, args.seq,
                        args.batch, seed=args.seed)
    else:
        data = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                           seed=args.seed)

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, schedule=adamw.cosine_schedule(
            warmup=max(args.steps // 20, 1), total=args.steps))

    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, log_every=10)
    # the finite-check skip/rollback path reuses pre-step buffers, which
    # donation would have freed on device — only donate when the guard is
    # off (Trainer rejects the inconsistent combination at init)
    donate = not tcfg.finite_checks

    n_dev = jax.device_count()
    if n_dev > 1:
        mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
        with dctx.use_mesh(mesh):
            batch0 = jax.tree.map(jax.numpy.asarray, data.batch(0))
            step = jit_train_step(mesh, model, opt_cfg,
                                  jax.eval_shape(lambda: batch0),
                                  n_micro=args.n_micro, seed=args.seed,
                                  donate=donate)
            _run(model, opt_cfg, data, step, tcfg, donate)
    else:
        step = jax.jit(make_train_step(model, opt_cfg, n_micro=args.n_micro,
                                       seed=args.seed),
                       donate_argnums=(0, 1) if donate else ())
        _run(model, opt_cfg, data, step, tcfg, donate)


def _run(model, opt_cfg, data, step, tcfg, donate):
    trainer = Trainer(model, opt_cfg, data, step, tcfg,
                      step_donates=donate)
    out = trainer.run()
    print(f"finished {out['steps']} steps in {out['wall_s']:.1f}s; "
          f"final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
