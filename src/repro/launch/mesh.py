"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e); multi-pod adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


HW = {
    # TPU v5e per-chip hardware constants used by the roofline model
    "peak_bf16_flops": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
}
