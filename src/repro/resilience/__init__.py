"""repro.resilience — fault injection + graceful degradation.

Two halves, importable as ``from repro import resilience``:

- **injection** (:mod:`.injection`): named, seeded, deterministic fault
  injection points on the hot paths.  ``resilience.inject("ckpt.write")``
  is a no-op in production; ``with resilience.chaos(Fault(...)):``
  activates raise / delay / corrupt faults for tests and chaos drills.
- **guards** (:mod:`.guards`): host-side finite checks
  (``is_finite`` / ``tree_finite`` / ``check_finite``) used by the serve
  engine's degradation ladder and the trainer's skip-step logic.

Recovery events are counted under the ``resilience.*`` prefix in the
``repro.obs`` registry — ``resilience.injected.<point>``,
``resilience.serve.*``, ``resilience.train.*``, ``resilience.ckpt.*`` —
so every degradation is observable.  See ROADMAP.md § Robustness.
"""
from .guards import NonFiniteError, check_finite, is_finite, tree_finite
from .injection import (CANONICAL_POINTS, Fault, FaultInjected, active,
                        chaos, inject, points)

__all__ = [
    "CANONICAL_POINTS", "Fault", "FaultInjected", "active", "chaos",
    "inject", "points",
    "NonFiniteError", "check_finite", "is_finite", "tree_finite",
]
