"""Numeric guards: cheap host-side finite checks at recovery decision
points (wave logits, per-step loss/grad-norm).  These run where the value
has already been synced to host, so they add no device round-trips."""
from __future__ import annotations

import math
from typing import Any

import numpy as np


class NonFiniteError(FloatingPointError):
    """A guarded value (logits, loss, grads) came back NaN/Inf."""


def is_finite(value) -> bool:
    """True iff a scalar / array is entirely finite (NaN/Inf-free)."""
    if isinstance(value, (int, float)):
        return math.isfinite(value)
    arr = np.asarray(value)
    if not np.issubdtype(arr.dtype, np.floating):
        return True
    return bool(np.isfinite(arr).all())


def tree_finite(tree: Any) -> bool:
    """True iff every float leaf of a pytree is finite."""
    import jax
    return all(is_finite(leaf) for leaf in jax.tree_util.tree_leaves(tree))


def check_finite(value, what: str):
    """Return ``value`` or raise :class:`NonFiniteError` naming ``what``."""
    if not is_finite(value):
        raise NonFiniteError(f"non-finite values in {what}")
    return value
