"""Deterministic fault injection for robustness testing.

A small registry of *named injection points* threaded through the hot
paths (``resilience.inject("serve.prefill", value)``).  In production the
call is a near-free no-op (one empty-list check, no lock).  Tests and
chaos benchmarks activate faults with::

    with resilience.chaos(Fault("ckpt.write", mode="raise")):
        trainer.run()          # every checkpoint write now fails

Faults are **deterministic**: each fault fires on an explicit hit window
(``after`` skipped hits, then up to ``times`` firings) or, when ``p < 1``,
on a seeded per-fault PRNG — identical runs inject identically, which is
what makes the recovery tests reproducible.

Modes:
  * ``raise``   — raise ``exc`` (default :class:`FaultInjected`) at the point;
  * ``delay``   — sleep ``delay_s`` then pass the value through (stalls,
    stragglers, hung-collective stand-ins);
  * ``corrupt`` — return ``corrupt(value)`` (default: NaN-poison floats /
    float arrays) instead of the real value.

Every firing increments ``resilience.injected.<point>`` in the active
``repro.obs`` registry.  Plans are process-global (guarded by a lock) so
faults are visible to side threads — the async checkpointer writes on a
worker thread and must still see an active ``ckpt.write`` fault.

Canonical points (auto-registered on first use, pre-seeded here so tools
can enumerate them): see :data:`CANONICAL_POINTS`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random as _random
import threading
import time
from typing import Callable, Iterator, Optional

from repro import obs


class FaultInjected(RuntimeError):
    """Raised by an active ``mode="raise"`` fault at an injection point."""


#: Injection points wired through the codebase (kept in sync with call
#: sites; ``inject`` auto-registers unknown names so the set never gates).
CANONICAL_POINTS = (
    "serve.prefill",      # prefill logits (corrupt -> NaN logits)
    "serve.insert",       # per-slot insertion logits (corrupt -> NaN)
    "serve.decode",       # decode loop entry (raise/delay)
    "train.step",         # before train_step (delay -> slow step)
    "train.loss",         # post-step loss value (corrupt -> NaN loss)
    "ckpt.write",         # inside checkpoint save (raise -> failed write)
    "data.batch",         # data pipeline batch (delay -> input stall)
    "amm.probs",          # sampling probabilities (corrupt -> degenerate p)
)


def _nan_poison(value):
    """Default corruption: NaN floats / float arrays, identity otherwise."""
    if value is None:
        return value
    import numpy as np
    if isinstance(value, float):
        return float("nan")
    try:
        arr = np.asarray(value)
    except Exception:                                      # noqa: BLE001
        return value
    if not np.issubdtype(arr.dtype, np.floating):
        return value
    out = np.array(arr, copy=True)
    out.flat[: max(1, out.size // 7)] = np.nan
    return out


@dataclasses.dataclass
class Fault:
    """One activated fault at a named injection point.

    Fires on hit numbers ``after <= n < after + times`` of the point
    (``times=None`` = every hit from ``after`` on), optionally thinned by
    a seeded coin with probability ``p``.
    """

    point: str
    mode: str = "raise"                       # raise | delay | corrupt
    exc: Optional[BaseException] = None       # for mode="raise"
    delay_s: float = 0.05                     # for mode="delay"
    corrupt: Optional[Callable] = None        # for mode="corrupt"
    after: int = 0
    times: Optional[int] = 1
    p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.mode not in ("raise", "delay", "corrupt"):
            raise ValueError(f"unknown fault mode {self.mode!r}")
        self._rng = _random.Random(self.seed)
        self._hits = 0
        self._fired = 0

    def _should_fire(self) -> bool:
        n = self._hits
        self._hits += 1
        if n < self.after:
            return False
        if self.times is not None and self._fired >= self.times:
            return False
        if self.p < 1.0 and self._rng.random() >= self.p:
            return False
        self._fired += 1
        return True


_lock = threading.Lock()
_plans: list = []          # list of active fault lists (stack of chaos())
_points = set(CANONICAL_POINTS)


def points() -> tuple:
    """Registered injection point names (sorted)."""
    with _lock:
        return tuple(sorted(_points))


def active() -> bool:
    return bool(_plans)


def inject(point: str, value=None):
    """Pass ``value`` through the named injection point.

    No active chaos plan: returns ``value`` untouched (fast path, no
    lock).  Otherwise the innermost matching fault fires per its mode.
    """
    if not _plans:                     # production fast path
        return value
    with _lock:
        _points.add(point)
        fault = None
        for plan in reversed(_plans):
            for f in plan:
                if f.point == point and f._should_fire():
                    fault = f
                    break
            if fault is not None:
                break
    if fault is None:
        return value
    obs.get_registry().counter(f"resilience.injected.{point}").inc()
    if fault.mode == "raise":
        raise fault.exc if fault.exc is not None else FaultInjected(point)
    if fault.mode == "delay":
        time.sleep(fault.delay_s)
        return value
    fn = fault.corrupt if fault.corrupt is not None else _nan_poison
    return fn(value)


@contextlib.contextmanager
def chaos(*faults) -> Iterator[list]:
    """Activate faults for the dynamic extent of the block.

    Accepts :class:`Fault` instances or bare point-name strings (shorthand
    for ``Fault(point, mode="raise")``).  Plans nest; the innermost plan
    wins for a given point.  Visible across threads by design.
    """
    plan = [Fault(f) if isinstance(f, str) else f for f in faults]
    with _lock:
        _plans.append(plan)
        for f in plan:
            _points.add(f.point)
    try:
        yield plan
    finally:
        with _lock:
            _plans.remove(plan)
